"""Paper Figure 10: throughput trend with increasing problem size.

Expectation from the paper: throughput climbs until resources saturate,
then plateaus. On CPU the same qualitative curve appears (dispatch overhead
amortizes, then memory bandwidth saturates).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import StencilEngine
from repro.core.stencil import make_stencil

SIZES = (64, 128, 256, 512, 1024, 2048)


def run(iters: int = 5) -> List[dict]:
    rows = []
    for shape, r in (("box", 2), ("star", 2)):
        spec = make_stencil(shape, 2, r, seed=3)
        eng = StencilEngine(spec, backend="sptc")
        for n in SIZES:
            x = jnp.asarray(np.random.default_rng(0).normal(
                size=(n + 2 * r, n + 2 * r)).astype(np.float32))
            y = eng(x)
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            for _ in range(iters):
                y = eng(x)
            jax.block_until_ready(y)
            dt = (time.perf_counter() - t0) / iters
            rows.append({"stencil": spec.name, "n": n,
                         "gstencils": n * n / dt / 1e9})
    return rows


def main():
    print("# Fig 10 — SPTC-backend throughput vs problem size")
    print("stencil,n,gstencils_per_s")
    rows = run()
    for row in rows:
        print(f"{row['stencil']},{row['n']},{row['gstencils']:.3f}")
    # qualitative check: large >= small (saturation curve)
    by = {}
    for row in rows:
        by.setdefault(row["stencil"], []).append(row["gstencils"])
    for k, v in by.items():
        print(f"# {k}: small {v[0]:.3f} -> large {v[-1]:.3f} "
              f"({v[-1]/max(v[0],1e-9):.1f}x scaling gain)")


if __name__ == "__main__":
    main()
