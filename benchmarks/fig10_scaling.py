"""Paper Figure 10: scaling — problem size (strong) and device count (weak).

Two sweeps:

  * **strong** (the original figure): single-device SPTC throughput vs
    problem size.  Expectation from the paper: throughput climbs until
    resources saturate, then plateaus (on CPU the same qualitative curve
    appears — dispatch overhead amortizes, then bandwidth saturates).

  * **weak** (`--weak`): fixed per-device grid, increasing device count.
    Each point runs ``ShardedStencilEngine.iterate`` on a 1-D mesh over
    the first n devices with an n·B × W interior — perfect weak scaling
    keeps time/step flat (efficiency = t1/tn → 1.0).  Runnable on CPU
    with virtual devices::

        PYTHONPATH=src python benchmarks/fig10_scaling.py \\
            --weak --devices 8 --out BENCH_scaling.json

    ``--devices N`` sets ``XLA_FLAGS=--xla_force_host_platform_device_``
    ``count=N`` and therefore must act before jax first initializes —
    this module defers every jax import into the sweep functions for
    exactly that reason.  On a real multi-device platform, omit it.

``--out`` writes the versioned ``BENCH_scaling.json`` artifact that CI
uploads per build (see the ``distributed`` job in ci.yml).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional

SIZES = (64, 128, 256, 512, 1024, 2048)
QUICK_SIZES = (64, 128, 256)
ARTIFACT_VERSION = 1


def run(iters: int = 5, sizes=SIZES) -> List[dict]:
    """Strong sweep: single-device throughput vs problem size."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.engine import StencilEngine
    from repro.core.stencil import make_stencil

    rows = []
    for shape, r in (("box", 2), ("star", 2)):
        spec = make_stencil(shape, 2, r, seed=3)
        eng = StencilEngine(spec, backend="sptc")
        for n in sizes:
            x = jnp.asarray(np.random.default_rng(0).normal(
                size=(n + 2 * r, n + 2 * r)).astype(np.float32))
            y = eng(x)
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            for _ in range(iters):
                y = eng(x)
            jax.block_until_ready(y)
            dt = (time.perf_counter() - t0) / iters
            rows.append({"stencil": spec.name, "n": n,
                         "gstencils": n * n / dt / 1e9})
    return rows


def run_weak(per_device: int = 256, width: int = 256, steps: int = 8,
             iters: int = 3, device_counts=None) -> List[dict]:
    """Weak sweep: fixed per-device block, growing 1-D mesh.

    Grid is (n · per_device) × width over n devices; each measured call
    is ``iterate(u, steps)`` — state device-resident, one halo exchange
    (2 ppermutes) per step.  Reports time per step and weak-scaling
    efficiency t1/tn (1.0 = perfect).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.stencil import make_stencil
    from repro.distributed.halo import ShardedStencilEngine, grid_mesh

    avail = jax.device_count()
    if device_counts is None:
        device_counts = [n for n in (1, 2, 4, 8, 16) if n <= avail]
    rows = []
    for shape, r in (("star", 1), ("box", 1)):
        spec = make_stencil(shape, 2, r, seed=3)
        t1: Optional[float] = None
        for n in device_counts:
            eng = ShardedStencilEngine(spec, grid_mesh((n,)),
                                       backend="sptc")
            u = jnp.asarray(np.random.default_rng(0).normal(
                size=(n * per_device, width)).astype(np.float32))
            y = eng.iterate(u, steps)
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            for _ in range(iters):
                y = eng.iterate(u, steps)
            jax.block_until_ready(y)
            dt = (time.perf_counter() - t0) / iters / steps
            if t1 is None:
                t1 = dt
            rows.append({
                "stencil": spec.name, "devices": n,
                "grid": [n * per_device, width],
                "us_per_step": dt * 1e6,
                "gstencils": n * per_device * width / dt / 1e9,
                "efficiency": t1 / dt if dt > 0 else 0.0,
            })
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--weak", action="store_true",
                    help="run the weak-scaling sweep (needs >1 device "
                         "unless --devices forces virtual ones)")
    ap.add_argument("--strong", action="store_true",
                    help="run the strong (problem-size) sweep; default "
                         "when no sweep flag is given")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="force N virtual host CPU devices (sets XLA_FLAGS; "
                         "must run before jax initializes)")
    ap.add_argument("--per-device", type=int, default=256,
                    help="weak sweep: interior rows per device")
    ap.add_argument("--steps", type=int, default=8,
                    help="weak sweep: iterate() steps per measured call")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="CI profile: fewer/smaller strong-sweep sizes")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write the versioned BENCH_scaling.json")
    args = ap.parse_args(argv)

    if args.devices:
        if "jax" in sys.modules:
            print(f"# --devices {args.devices} ignored: jax is already "
                  "initialized in this process", file=sys.stderr)
        else:
            flag = (f"--xla_force_host_platform_device_count"
                    f"={args.devices}")
            os.environ["XLA_FLAGS"] = " ".join(
                [f for f in (os.environ.get("XLA_FLAGS"), flag) if f])
    do_strong = args.strong or not args.weak
    artifact: dict = {"version": ARTIFACT_VERSION}

    if do_strong:
        print("# Fig 10 — SPTC-backend throughput vs problem size")
        print("stencil,n,gstencils_per_s")
        rows = run(iters=args.iters,
                   sizes=QUICK_SIZES if args.quick else SIZES)
        for row in rows:
            print(f"{row['stencil']},{row['n']},{row['gstencils']:.3f}")
        # qualitative check: large >= small (saturation curve)
        by: dict = {}
        for row in rows:
            by.setdefault(row["stencil"], []).append(row["gstencils"])
        for k, v in by.items():
            print(f"# {k}: small {v[0]:.3f} -> large {v[-1]:.3f} "
                  f"({v[-1]/max(v[0],1e-9):.1f}x scaling gain)")
        artifact["strong"] = rows

    if args.weak:
        import jax
        print(f"# Fig 10b — weak scaling over {jax.device_count()} "
              "device(s), fixed per-device grid")
        print("stencil,devices,us_per_step,gstencils_per_s,efficiency")
        rows = run_weak(per_device=args.per_device, steps=args.steps,
                        iters=args.iters)
        for row in rows:
            print(f"{row['stencil']},{row['devices']},"
                  f"{row['us_per_step']:.1f},{row['gstencils']:.3f},"
                  f"{row['efficiency']:.2f}")
        artifact["weak"] = rows
        artifact["weak_meta"] = {
            "per_device_rows": args.per_device,
            "steps": args.steps,
            "device_count": jax.device_count(),
            "backend": jax.default_backend(),
        }

    if args.out:
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
