"""Paper Figure 9: throughput (GStencils/s) across stencil shapes.

The paper measures GPU kernels; this container is CPU-only, so we measure
the jit-compiled CPU executables of each execution paradigm — the RELATIVE
ordering and the analytic projection are the reproducible content:

  direct   pointwise shifted FMA          (CUDA-core baseline analogue)
  gemm     dense kernel-matrix GEMM       (TCStencil/dense-TC analogue —
                                           carries the 2x padded-zero MACs)
  sptc     2:4-compressed execution       (SPTCStencil: halved reduction)

plus the ANALYTIC TPU projection: MAC counts from core/analysis scaled by
v5e peak — the number the roofline table cross-checks. Pallas kernels are
excluded here (interpret=True is a correctness harness, not a timer).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import StencilEngine
from repro.core.stencil import PAPER_SUITE, make_stencil

SIZES_1D = 1_048_576            # ~1M points, paper uses 10.24M
SIZES_2D = (1024, 1024)         # paper uses 10240^2; CPU-scaled


def bench_engine(eng: StencilEngine, x, iters: int = 5) -> float:
    y = eng(x)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(iters):
        y = eng(x)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters


def run(iters: int = 5) -> List[Dict]:
    rows = []
    for shape, ndim, r in PAPER_SUITE:
        spec = make_stencil(shape, ndim, r, seed=17 * ndim + r)
        if ndim == 1:
            dims = (SIZES_1D,)
        else:
            dims = SIZES_2D
        pts = float(np.prod(dims))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=tuple(s + 2 * r for s in dims)).astype(np.float32))
        row = {"stencil": spec.name, "points": pts}
        for backend in ("direct", "gemm", "sptc"):
            eng = StencilEngine(spec, backend=backend)
            dt = bench_engine(eng, x, iters)
            row[f"{backend}_gstencils"] = pts / dt / 1e9
        # §Perf D: fused-rows execution (box-2D GEMM/SpTC paths)
        for backend in ("gemm", "sptc"):
            eng = StencilEngine(spec, backend=backend, fuse_rows=True)
            dt = bench_engine(eng, x, iters)
            row[f"{backend}_fused_gstencils"] = pts / dt / 1e9
        # analytic TPU projection (compute-term GStencils/s at v5e peak)
        taps = spec.taps
        dense_k = 2 * (2 * r + 2)          # padded GEMM reduction width
        row["tpu_dense_proj"] = 197e12 / (2 * dense_k * (taps / (2 * r + 1))) / 1e9
        row["tpu_sptc_proj"] = row["tpu_dense_proj"] * 2
        rows.append(row)
    return rows


def main():
    print("# Fig 9 — stencil throughput by execution paradigm (CPU measured"
          " + TPU analytic projection)")
    rows = run()
    cols = ["stencil", "direct_gstencils", "gemm_gstencils",
            "sptc_gstencils", "gemm_fused_gstencils",
            "sptc_fused_gstencils", "tpu_dense_proj", "tpu_sptc_proj"]
    print(",".join(cols))
    for row in rows:
        print(",".join(f"{row[c]:.3f}" if isinstance(row[c], float)
                       else str(row[c]) for c in cols))
    sp = [r["sptc_gstencils"] / r["gemm_gstencils"] for r in rows]
    print(f"# sptc vs dense-gemm speedup (CPU, semantic): "
          f"geomean {float(np.exp(np.mean(np.log(sp)))):.2f}x")


if __name__ == "__main__":
    main()
