"""Kernel-level microbench: the three stencil execution paradigms at the
SpMM level (what §3.4's kernel engineering targets), CPU wall-clock.

Measures the jnp (XLA-compiled) forms — the Pallas kernels are validated in
interpret mode (correctness harness) and are not timed here.  Records the
results as a **versioned JSON artifact** (``BENCH_kernels.json``) mirroring
``serving_bench.py``'s ``BENCH_serving.json``: per-radius dense-GEMM vs
compressed 2:4 SpMM time and useful-MAC throughput, plus the end-to-end
tuned-vs-default engine comparison per stencil.

    PYTHONPATH=src python benchmarks/kernel_bench.py --out BENCH_kernels.json
    PYTHONPATH=src python benchmarks/kernel_bench.py --quick   # CI profile
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsify import sparsify_stencil_kernel
from repro.core.sptc import sptc_matmul
from repro.core.transform import kernel_matrix

SCHEMA = "repro/bench_kernels"
VERSION = 1


def bench(fn, *args, iters=20):
    y = fn(*args)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters


def spmm_sweep(radii, n, iters, seed=0):
    """Per-radius dense padded GEMM vs compressed 2:4 SpMM rows."""
    rng = np.random.default_rng(seed)
    rows = []
    for r in radii:
        w = rng.normal(size=2 * r + 1)
        sk = sparsify_stencil_kernel(w)
        L = sk.L
        K = jnp.asarray(kernel_matrix(w, L=L, pad_width=True), jnp.float32)
        x = jnp.asarray(rng.normal(size=(2 * L, n)), jnp.float32)
        vals = jnp.asarray(sk.values, jnp.float32)
        meta = jnp.asarray(sk.meta)
        xp = x[np.asarray(sk.perm)]

        dense = jax.jit(lambda K, x: K @ x)
        sptc = jax.jit(sptc_matmul)
        td = bench(dense, K, x, iters=iters)
        ts = bench(sptc, vals, meta, xp, iters=iters)
        dmacs = L * 2 * L * n
        smacs = L * L * n
        rows.append({
            "radius": r, "L": L, "n": n,
            "dense_us": round(td * 1e6, 1),
            "sptc_us": round(ts * 1e6, 1),
            "dense_gmacs": round(dmacs / td / 1e9, 2),
            "sptc_gmacs": round(smacs / ts / 1e9, 2),
        })
    return rows


def tuned_stencil_sweep(points, n, iters, seed=1):
    """End-to-end: default direct engine vs the tuner's measured plan."""
    from repro.core.stencil import make_stencil
    from repro.tuner import PlanCache, plan_for
    from repro.tuner.plan import Plan
    from repro.tuner.search import measure

    cache = PlanCache()
    rng = np.random.default_rng(seed)
    rows = []
    for shape, ndim, r in points:
        spec = make_stencil(shape, ndim, r, seed=9)
        x = jnp.asarray(rng.normal(size=(n + 2 * r, n + 2 * r)), jnp.float32)
        plan = plan_for(spec, x.shape, x.dtype, cache=cache, iters=iters)
        td = measure(cache.engine(spec, Plan.default(spec)), x, iters=2 * iters)
        tt = measure(cache.engine(spec, plan), x, iters=2 * iters)
        rows.append({
            "stencil": spec.name, "plan": plan.describe(),
            "default_us": round(td * 1e6, 1),
            "tuned_us": round(tt * 1e6, 1),
            "speedup": round(td / tt, 2),
        })
    return rows, cache.stats.as_dict()


def run(radii=(1, 2, 3, 5, 7), n=1 << 14, iters=20, tuned_n=256,
        tuned_iters=5, seed=0, out=None):
    spmm = spmm_sweep(radii, n, iters, seed=seed)
    tuned, tuner_stats = tuned_stencil_sweep(
        (("star", 2, 1), ("box", 2, 2), ("box", 2, 3)),
        tuned_n, tuned_iters)
    payload = {
        "schema": SCHEMA,
        "version": VERSION,
        "generated_unix": round(time.time(), 1),
        "env": {"backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "python": platform.python_version(),
                "jax": jax.__version__},
        "config": {"radii": list(radii), "n": n, "iters": iters,
                   "tuned_n": tuned_n, "tuned_iters": tuned_iters,
                   "seed": seed},
        "spmm": spmm,
        "tuned_vs_default": tuned,
        "tuner": tuner_stats,
    }
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=None,
                    help="SpMM columns (default: 16384, 2048 in --quick)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small CI profile (fewer columns/iters/radii)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)

    radii = (1, 2, 3) if args.quick else (1, 2, 3, 5, 7)
    n = args.n or (1 << 11 if args.quick else 1 << 14)
    iters = args.iters or (5 if args.quick else 20)
    tuned_n = 64 if args.quick else 256
    payload = run(radii=radii, n=n, iters=iters, tuned_n=tuned_n,
                  tuned_iters=3 if args.quick else 5, out=args.out)

    print("# kernel microbench: dense padded GEMM vs compressed 2:4 SpMM")
    print("radius,L,n,dense_us,sptc_us,dense_gmacs,sptc_gmacs")
    for row in payload["spmm"]:
        print(f"{row['radius']},{row['L']},{row['n']},{row['dense_us']},"
              f"{row['sptc_us']},{row['dense_gmacs']},{row['sptc_gmacs']}")
    print("# sptc executes K/2 — per-useful-MAC throughput is the metric")
    print()
    print("# end-to-end stencil: default direct engine vs repro.tuner plan")
    print("stencil,plan,default_us,tuned_us,speedup")
    for row in payload["tuned_vs_default"]:
        print(f"{row['stencil']},{row['plan']},{row['default_us']},"
              f"{row['tuned_us']},{row['speedup']}x")
    print(f"# tuner cache: {payload['tuner']}")
    if args.out:
        print(f"# artifact written to {args.out}")
    return payload


if __name__ == "__main__":
    main()
