"""Kernel-level microbench: the three stencil execution paradigms at the
SpMM level (what §3.4's kernel engineering targets), CPU wall-clock.

Measures the jnp (XLA-compiled) forms — the Pallas kernels are validated in
interpret mode (correctness harness) and are not timed here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsify import sparsify_stencil_kernel
from repro.core.sptc import sptc_matmul
from repro.core.transform import kernel_matrix


def bench(fn, *args, iters=20):
    y = fn(*args)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters


def tuned_stencil_bench():
    """End-to-end: default direct engine vs the tuner's measured plan."""
    from repro.core.stencil import make_stencil
    from repro.tuner import PlanCache, plan_for
    from repro.tuner.plan import Plan
    from repro.tuner.search import measure

    print()
    print("# end-to-end stencil: default direct engine vs repro.tuner plan")
    print("stencil,plan,default_us,tuned_us,speedup")
    cache = PlanCache()
    rng = np.random.default_rng(1)
    n = 256
    for shape, ndim, r in (("star", 2, 1), ("box", 2, 2), ("box", 2, 3)):
        spec = make_stencil(shape, ndim, r, seed=9)
        x = jnp.asarray(rng.normal(size=(n + 2 * r, n + 2 * r)), jnp.float32)
        plan = plan_for(spec, x.shape, x.dtype, cache=cache, iters=5)
        td = measure(cache.engine(spec, Plan.default(spec)), x, iters=10)
        tt = measure(cache.engine(spec, plan), x, iters=10)
        print(f"{spec.name},{plan.describe()},{td*1e6:.1f},{tt*1e6:.1f},"
              f"{td/tt:.2f}x")
    print(f"# tuner cache: {cache.stats.as_dict()}")


def main():
    print("# kernel microbench: dense padded GEMM vs compressed 2:4 SpMM")
    print("radius,L,n,dense_us,sptc_us,dense_gmacs,sptc_gmacs")
    rng = np.random.default_rng(0)
    n = 1 << 14
    for r in (1, 2, 3, 5, 7):
        w = rng.normal(size=2 * r + 1)
        sk = sparsify_stencil_kernel(w)
        L = sk.L
        K = jnp.asarray(kernel_matrix(w, L=L, pad_width=True), jnp.float32)
        x = jnp.asarray(rng.normal(size=(2 * L, n)), jnp.float32)
        vals = jnp.asarray(sk.values, jnp.float32)
        meta = jnp.asarray(sk.meta)
        xp = x[np.asarray(sk.perm)]

        dense = jax.jit(lambda K, x: K @ x)
        sptc = jax.jit(sptc_matmul)
        td = bench(dense, K, x)
        ts = bench(sptc, vals, meta, xp)
        dmacs = L * 2 * L * n
        smacs = L * L * n
        print(f"{r},{L},{n},{td*1e6:.1f},{ts*1e6:.1f},"
              f"{dmacs/td/1e9:.2f},{smacs/ts/1e9:.2f}")
    print("# sptc executes K/2 — per-useful-MAC throughput is the metric")
    tuned_stencil_bench()


if __name__ == "__main__":
    main()
