"""Kernel-level microbench: the three stencil execution paradigms at the
SpMM level (what §3.4's kernel engineering targets), CPU wall-clock.

Measures the jnp (XLA-compiled) forms — the Pallas kernels run in
interpret mode off-TPU (correctness harness, Python speed); their rows
report correctness vs the direct oracle plus the **TPU v5e roofline
time** the fused program targets (``roofline/analysis.py``), with the
interpret-mode wall clock recorded only for provenance.  Records the
results as a **versioned JSON artifact** (``BENCH_kernels.json``)
mirroring ``serving_bench.py``'s ``BENCH_serving.json``: per-radius
dense-GEMM vs compressed 2:4 SpMM time and useful-MAC throughput, the
fused pallas_sptc v2 kernel sweep (general / star-fast / bf16 paths vs
the direct oracle, registry × radius/L), plus the end-to-end
tuned-vs-default engine comparison per stencil.

    PYTHONPATH=src python benchmarks/kernel_bench.py --out BENCH_kernels.json
    PYTHONPATH=src python benchmarks/kernel_bench.py --quick   # CI profile
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsify import sparsify_stencil_kernel
from repro.core.sptc import sptc_matmul
from repro.core.transform import kernel_matrix

SCHEMA = "repro/bench_kernels"
VERSION = 2


def bench(fn, *args, iters=20):
    y = fn(*args)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters


def spmm_sweep(radii, n, iters, seed=0):
    """Per-radius dense padded GEMM vs compressed 2:4 SpMM rows."""
    rng = np.random.default_rng(seed)
    rows = []
    for r in radii:
        w = rng.normal(size=2 * r + 1)
        sk = sparsify_stencil_kernel(w)
        L = sk.L
        K = jnp.asarray(kernel_matrix(w, L=L, pad_width=True), jnp.float32)
        x = jnp.asarray(rng.normal(size=(2 * L, n)), jnp.float32)
        vals = jnp.asarray(sk.values, jnp.float32)
        meta = jnp.asarray(sk.meta)
        xp = x[np.asarray(sk.perm)]

        dense = jax.jit(lambda K, x: K @ x)
        sptc = jax.jit(sptc_matmul)
        td = bench(dense, K, x, iters=iters)
        ts = bench(sptc, vals, meta, xp, iters=iters)
        dmacs = L * 2 * L * n
        smacs = L * L * n
        rows.append({
            "radius": r, "L": L, "n": n,
            "dense_us": round(td * 1e6, 1),
            "sptc_us": round(ts * 1e6, 1),
            "dense_gmacs": round(dmacs / td / 1e9, 2),
            "sptc_gmacs": round(smacs / ts / 1e9, 2),
        })
    return rows


def fused_kernel_sweep(radii, n, seed=2):
    """Fused pallas_sptc v2 vs the direct oracle, with roofline fractions.

    All three kernel paths (general one-hot, star-fast banded, bf16
    compute) run in interpret mode and are checked allclose against the
    NumPy direct stencil.  The roofline columns model the TPU v5e target:
    ``roofline_us`` is the two-term hardware-limit time for the fused
    program's FLOPs/bytes; ``attained_frac_interp`` divides that by the
    measured wall clock — on CPU interpret mode this is (intentionally)
    tiny and recorded only for provenance, on a real TPU the same code
    path reports the true attained fraction.
    """
    from repro.core.sparsify import sparsify_stencil_kernel
    from repro.kernels.sptc_spmm.ops import sptc_spmm_fused
    from repro.roofline.analysis import (attained_fraction,
                                         kernel_roofline_time)
    rng = np.random.default_rng(seed)
    rows = []
    for r in radii:
        w = rng.normal(size=2 * r + 1)
        sk = sparsify_stencil_kernel(w)
        L = sk.L
        n_out = 4 * L
        x = rng.normal(size=(n_out + 2 * r, n)).astype(np.float32)
        want = np.stack([np.tensordot(w, x[i:i + 2 * r + 1], axes=(0, 0))
                         for i in range(n_out)])
        x2 = jnp.asarray(x)

        def run_path(star_fast, compute_dtype=None):
            fn = lambda: sptc_spmm_fused(
                sk.sparse, sk.perm, x2, n_out=n_out, L=L,
                star_fast=star_fast, compute_dtype=compute_dtype)
            t = bench(lambda: fn(), iters=3)
            err = float(np.max(np.abs(np.asarray(fn()) - want)))
            return t, err

        t_gen, err_gen = run_path(False)
        t_star, err_star = run_path("auto")
        _, err_bf16 = run_path("auto", "bfloat16")
        tol = 2e-4 * max(1.0, float(np.max(np.abs(want))))
        # fused program work: K/2 = L MACs per output point (the 2:4
        # compression halves the dense 2L), streamed input + output bytes
        tiles = -(-n_out // L)
        flops = 2.0 * n_out * n * L
        hbm_bytes = 4.0 * n * ((tiles + 1) * L + n_out)
        rows.append({
            "radius": r, "L": L, "n_out": n_out, "n": n,
            "general_ok": bool(err_gen <= tol),
            "star_fast_ok": bool(err_star <= tol),
            "bf16_ok": bool(err_bf16 <= 0.05 * max(
                1.0, float(np.max(np.abs(want))))),
            "max_err_f32": round(max(err_gen, err_star), 8),
            "max_err_bf16": round(err_bf16, 6),
            "roofline_us": round(
                kernel_roofline_time(flops, hbm_bytes) * 1e6, 4),
            "interp_cpu_us": round(t_star * 1e6, 1),
            "attained_frac_interp": round(
                attained_fraction(t_star, flops, hbm_bytes), 8),
        })
    return rows


def fused_engine_sweep(points, n, seed=3):
    """Engine-level pallas_sptc (fused v2) vs the direct oracle, over the
    stencil registry (shape × ndim) × radius — each point reports the
    plan's L and the max abs error."""
    from repro.core.engine import StencilEngine
    from repro.core.stencil import make_stencil
    rng = np.random.default_rng(seed)
    rows = []
    for shape, ndim, r in points:
        spec = make_stencil(shape, ndim, r, seed=11)
        dims = (n + 2 * r,) * ndim
        x = jnp.asarray(rng.normal(size=dims), jnp.float32)
        want = np.asarray(StencilEngine(spec, backend="direct")(x))
        eng = StencilEngine(spec, backend="pallas_sptc")
        got = np.asarray(eng(x))
        err = float(np.max(np.abs(got - want)))
        tol = 2e-4 * max(1.0, float(np.max(np.abs(want))))
        rows.append({
            "stencil": spec.name, "L": eng.L,
            "max_err": round(err, 8), "allclose": bool(err <= tol),
        })
    return rows


def tuned_stencil_sweep(points, n, iters, seed=1):
    """End-to-end: default direct engine vs the tuner's measured plan."""
    from repro.core.stencil import make_stencil
    from repro.tuner import PlanCache, plan_for
    from repro.tuner.plan import Plan
    from repro.tuner.search import measure

    cache = PlanCache()
    rng = np.random.default_rng(seed)
    rows = []
    for shape, ndim, r in points:
        spec = make_stencil(shape, ndim, r, seed=9)
        x = jnp.asarray(rng.normal(size=(n + 2 * r, n + 2 * r)), jnp.float32)
        plan = plan_for(spec, x.shape, x.dtype, cache=cache, iters=iters)
        td = measure(cache.engine(spec, Plan.default(spec)), x, iters=2 * iters)
        tt = measure(cache.engine(spec, plan), x, iters=2 * iters)
        rows.append({
            "stencil": spec.name, "plan": plan.describe(),
            "default_us": round(td * 1e6, 1),
            "tuned_us": round(tt * 1e6, 1),
            "speedup": round(td / tt, 2),
        })
    return rows, cache.stats.as_dict()


#: the stencil registry the fused engine sweep validates against
REGISTRY = (("star", 1), ("box", 1), ("star", 2), ("box", 2))


def run(radii=(1, 2, 3, 5, 7), n=1 << 14, iters=20, tuned_n=256,
        tuned_iters=5, seed=0, out=None, fused_radii=(1, 2, 3),
        fused_n=512, fused_engine_n=24):
    spmm = spmm_sweep(radii, n, iters, seed=seed)
    fused_kernel = fused_kernel_sweep(fused_radii, fused_n)
    fused_engine = fused_engine_sweep(
        tuple((s, d, r) for s, d in REGISTRY for r in fused_radii),
        fused_engine_n)
    tuned, tuner_stats = tuned_stencil_sweep(
        (("star", 2, 1), ("box", 2, 2), ("box", 2, 3)),
        tuned_n, tuned_iters)
    payload = {
        "schema": SCHEMA,
        "version": VERSION,
        "generated_unix": round(time.time(), 1),
        "env": {"backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "python": platform.python_version(),
                "jax": jax.__version__},
        "config": {"radii": list(radii), "n": n, "iters": iters,
                   "tuned_n": tuned_n, "tuned_iters": tuned_iters,
                   "seed": seed},
        "spmm": spmm,
        "fused_kernel": fused_kernel,
        "fused_engine": fused_engine,
        "tuned_vs_default": tuned,
        "tuner": tuner_stats,
    }
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=None,
                    help="SpMM columns (default: 16384, 2048 in --quick)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small CI profile (fewer columns/iters/radii)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)

    radii = (1, 2, 3) if args.quick else (1, 2, 3, 5, 7)
    n = args.n or (1 << 11 if args.quick else 1 << 14)
    iters = args.iters or (5 if args.quick else 20)
    tuned_n = 64 if args.quick else 256
    payload = run(radii=radii, n=n, iters=iters, tuned_n=tuned_n,
                  tuned_iters=3 if args.quick else 5, out=args.out,
                  fused_radii=(1, 2) if args.quick else (1, 2, 3),
                  fused_n=256 if args.quick else 512,
                  fused_engine_n=16 if args.quick else 24)

    print("# kernel microbench: dense padded GEMM vs compressed 2:4 SpMM")
    print("radius,L,n,dense_us,sptc_us,dense_gmacs,sptc_gmacs")
    for row in payload["spmm"]:
        print(f"{row['radius']},{row['L']},{row['n']},{row['dense_us']},"
              f"{row['sptc_us']},{row['dense_gmacs']},{row['sptc_gmacs']}")
    print("# sptc executes K/2 — per-useful-MAC throughput is the metric")
    print()
    print("# fused pallas_sptc v2 (interpret mode) vs direct oracle")
    print("radius,L,general_ok,star_fast_ok,bf16_ok,roofline_us,"
          "interp_cpu_us")
    for row in payload["fused_kernel"]:
        print(f"{row['radius']},{row['L']},{row['general_ok']},"
              f"{row['star_fast_ok']},{row['bf16_ok']},"
              f"{row['roofline_us']},{row['interp_cpu_us']}")
    print("# roofline_us models TPU v5e; interp wall clock is CPU Python")
    print()
    print("# fused engine (registry x radius): pallas_sptc vs direct")
    for row in payload["fused_engine"]:
        print(f"{row['stencil']},L{row['L']},allclose={row['allclose']},"
              f"err={row['max_err']}")
    print()
    print("# end-to-end stencil: default direct engine vs repro.tuner plan")
    print("stencil,plan,default_us,tuned_us,speedup")
    for row in payload["tuned_vs_default"]:
        print(f"{row['stencil']},{row['plan']},{row['default_us']},"
              f"{row['tuned_us']},{row['speedup']}x")
    print(f"# tuner cache: {payload['tuner']}")
    if args.out:
        print(f"# artifact written to {args.out}")
    return payload


if __name__ == "__main__":
    main()
