"""Roofline table: render results/dryrun_*.jsonl as the per-(arch x cell x
mesh) three-term table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os
import sys
from typing import List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(paths=None) -> List[dict]:
    rows = []
    paths = paths or [os.path.join(RESULTS, f) for f in
                      sorted(os.listdir(RESULTS))
                      if f.startswith("dryrun") and f.endswith(".jsonl")]
    for p in paths:
        with open(p) as f:
            for line in f:
                rows.append(json.loads(line))
    return rows


def fmt_row(r: dict) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['cell']} | {r['mesh']} | — | — | — | "
                f"skipped ({r['skipped'][:40]}…) | — | — |")
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['cell']} | {r['mesh']} | — | — | — | "
                f"FAILED: {r.get('error', '?')[:50]} | — | — |")
    return ("| {arch} | {cell} | {mesh} | {tc:.4f} | {tm:.4f} | {tl:.4f} | "
            "{bn} | {uf:.2f} | {mfu:.3f} |").format(
        arch=r["arch"], cell=r["cell"], mesh=r["mesh"],
        tc=r["t_compute_s"], tm=r["t_memory_s"], tl=r["t_collective_s"],
        bn=r["bottleneck"], uf=r.get("useful_frac", 0),
        mfu=r.get("mfu_at_roofline", 0))


def main():
    try:
        rows = load(sys.argv[1:] or None)
    except FileNotFoundError:
        print("# no dry-run results yet — run repro.launch.dryrun first")
        return
    if not rows:
        print("# no dry-run results yet — run repro.launch.dryrun first")
        return
    print("| arch | cell | mesh | t_compute s | t_memory s | t_coll s | "
          "bottleneck | useful | MFU@roof |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"],
                                         r.get("mesh", ""))):
        print(fmt_row(r))
    ok = [r for r in rows if r.get("ok") and not r.get("skipped")
          and "t_compute_s" in r]
    if ok:
        import collections
        bn = collections.Counter(r["bottleneck"] for r in ok)
        print(f"\n# {len(ok)} compiled cells; bottleneck distribution: "
              f"{dict(bn)}")


if __name__ == "__main__":
    main()
