"""Roofline table: render results/dryrun_*.jsonl as the per-(arch x cell x
mesh) three-term table for EXPERIMENTS.md §Roofline, and emit the same rows
as a **versioned JSON artifact** (``BENCH_roofline.json``) mirroring
``kernel_bench.py``'s ``BENCH_kernels.json`` so CI archives the roofline
verdicts alongside the measured benchmarks::

    PYTHONPATH=src python benchmarks/roofline_table.py --out BENCH_roofline.json
    PYTHONPATH=src python benchmarks/roofline_table.py --quick   # CI profile

``--quick`` reads only the newest results file (CI keeps the artifact small
and current); with no results present the artifact still gets written, with
an empty table, so artifact consumers never 404.
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import platform
import time
from typing import List, Optional

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

SCHEMA = "repro/bench_roofline"
VERSION = 1


def result_paths(newest_only: bool = False) -> List[str]:
    try:
        names = sorted(f for f in os.listdir(RESULTS)
                       if f.startswith("dryrun") and f.endswith(".jsonl"))
    except FileNotFoundError:
        return []
    if newest_only and names:
        names = names[-1:]
    return [os.path.join(RESULTS, n) for n in names]


def load(paths: Optional[List[str]] = None,
         newest_only: bool = False) -> List[dict]:
    rows = []
    paths = paths if paths else result_paths(newest_only)
    for p in paths:
        with open(p) as f:
            for line in f:
                rows.append(json.loads(line))
    return rows


def summarize(rows: List[dict]) -> dict:
    ok = [r for r in rows if r.get("ok") and not r.get("skipped")
          and "t_compute_s" in r]
    return {
        "cells": len(rows),
        "compiled": len(ok),
        "skipped": sum(1 for r in rows if r.get("skipped")),
        "failed": sum(1 for r in rows
                      if not r.get("ok") and not r.get("skipped")),
        "bottlenecks": dict(collections.Counter(
            r["bottleneck"] for r in ok)),
    }


def payload(rows: List[dict], sources: List[str]) -> dict:
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated_unix": round(time.time(), 1),
        "env": {"python": platform.python_version(),
                "platform": platform.platform()},
        "sources": [os.path.basename(p) for p in sources],
        "summary": summarize(rows),
        "rows": rows,
    }


def fmt_row(r: dict) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['cell']} | {r['mesh']} | — | — | — | "
                f"skipped ({r['skipped'][:40]}…) | — | — |")
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['cell']} | {r['mesh']} | — | — | — | "
                f"FAILED: {r.get('error', '?')[:50]} | — | — |")
    return ("| {arch} | {cell} | {mesh} | {tc:.4f} | {tm:.4f} | {tl:.4f} | "
            "{bn} | {uf:.2f} | {mfu:.3f} |").format(
        arch=r["arch"], cell=r["cell"], mesh=r["mesh"],
        tc=r["t_compute_s"], tm=r["t_memory_s"], tl=r["t_collective_s"],
        bn=r["bottleneck"], uf=r.get("useful_frac", 0),
        mfu=r.get("mfu_at_roofline", 0))


def render(rows: List[dict]) -> None:
    if not rows:
        print("# no dry-run results yet — run repro.launch.dryrun first")
        return
    print("| arch | cell | mesh | t_compute s | t_memory s | t_coll s | "
          "bottleneck | useful | MFU@roof |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["cell"],
                                         r.get("mesh", ""))):
        print(fmt_row(r))
    s = summarize(rows)
    if s["compiled"]:
        print(f"\n# {s['compiled']} compiled cells; bottleneck "
              f"distribution: {s['bottlenecks']}")


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*",
                    help="explicit results/*.jsonl files (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="CI profile: newest results file only")
    ap.add_argument("--out", default=None,
                    help="also write the versioned JSON artifact here "
                         "(e.g. BENCH_roofline.json)")
    args = ap.parse_args(argv)
    sources = args.paths or result_paths(newest_only=args.quick)
    rows = load(sources)
    render(rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload(rows, sources), f, indent=1, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
