"""Benchmark aggregator: one section per paper table/figure + the roofline
table from the dry-run results.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time


def _section(title):
    print(f"\n{'='*72}\n== {title}\n{'='*72}", flush=True)


def main() -> None:
    t0 = time.time()
    from benchmarks import (fig9_throughput, fig10_scaling, kernel_bench,
                            roofline_table, serving_bench, table1_costs)
    _section("Table 1 — analytic cost model (paper §2.3/§3.2.3)")
    table1_costs.main()
    _section("Figure 9 — throughput across stencil shapes")
    fig9_throughput.main()
    _section("Figure 10 — throughput vs problem size")
    fig10_scaling.main([])
    _section("Kernel microbench — dense GEMM vs 2:4 SpMM")
    kernel_bench.main()
    _section("Serving driver — continuous batching (BENCH_serving.json)")
    serving_bench.main([], out="BENCH_serving.json", quick=True)
    _section("Roofline table — dry-run derived (EXPERIMENTS.md §Roofline)")
    roofline_table.main([])
    print(f"\n# benchmarks completed in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
