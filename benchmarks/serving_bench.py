"""Serving-driver benchmark: continuous-batching throughput + artifact.

Drives a randomized mix of stencil jobs (several specs × jittered
shapes × dtypes) through `repro.serving.StencilDriver` and records the
numbers the ROADMAP's perf trajectory needs as a **versioned JSON
artifact** (``BENCH_serving.json``): job throughput, batch occupancy,
padding efficiency, p50/p99 latency, tuned-vs-default speedup per spec,
and tuner plan-cache hit rates.  Every job's result is verified against
the per-job ``tuned_apply`` oracle before the artifact is written.

    PYTHONPATH=src python benchmarks/serving_bench.py --out BENCH_serving.json
    PYTHONPATH=src python benchmarks/serving_bench.py --quick   # CI profile
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import make_stencil
from repro.serving import BatchPolicy, StencilDriver
from repro.tuner import PlanCache, plan_for, tuned_apply
from repro.tuner.plan import Plan
from repro.tuner.search import measure

SCHEMA = "repro/bench_serving"
VERSION = 1


def _specs():
    return [make_stencil("star", 2, 1, seed=1),
            make_stencil("box", 2, 2, seed=2),
            make_stencil("box", 1, 1, seed=3)]


def _job_mix(specs, n_jobs, base, rng):
    """Randomized (spec, halo-inclusive array) jobs; shapes jitter inside
    one pow2 bucket per spec so plan groups see near-miss co-batching."""
    jobs = []
    for i in range(n_jobs):
        spec = specs[i % len(specs)]
        if spec.ndim == 2:
            dims = (int(rng.integers(base // 2 + 1, base + 1)),
                    int(rng.integers(base // 2 + 1, base + 1)))
        else:
            n = base * base
            dims = (int(rng.integers(n // 2 + 1, n + 1)),)
        shape = tuple(s + 2 * spec.radius for s in dims)
        jobs.append((spec, jnp.asarray(rng.normal(size=shape), jnp.float32)))
    return jobs


def _speedups(specs, cache, base, rng, iters):
    """Tuned-engine vs default(direct)-engine time per spec at full size."""
    out = {}
    for spec in specs:
        dims = ((base, base) if spec.ndim == 2 else (base * base,))
        shape = tuple(s + 2 * spec.radius for s in dims)
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        tuned_apply(spec, x, cache=cache)      # ensure a plan exists
        plan = plan_for(spec, x.shape, x.dtype, cache=cache)
        td = measure(cache.engine(spec, Plan.default(spec)), x, iters=iters)
        tt = measure(cache.engine(spec, plan), x, iters=iters)
        out[spec.name] = {"plan": plan.describe(),
                          "default_us": round(td * 1e6, 1),
                          "tuned_us": round(tt * 1e6, 1),
                          "speedup": round(td / tt, 3)}
    return out


def run(n_jobs=120, base=48, max_batch=16, max_wait_ms=5.0, mode="cost",
        padding="bucket", iters=5, seed=0, verify=True, out=None):
    rng = np.random.default_rng(seed)
    specs = _specs()
    cache = PlanCache()
    jobs = _job_mix(specs, n_jobs, base, rng)

    # warm pass: one job per plan group so the timed wave measures the
    # steady state (tuning + compiles happen here, not in-flight)
    with StencilDriver(cache=cache, mode=mode, padding=padding) as warm:
        seen = {}
        for spec, x in jobs:
            seen.setdefault(warm.group_key(spec, x), (spec, x))
        warm.map(seen.values())

    driver = StencilDriver(
        cache=cache, mode=mode, padding=padding,
        policy=BatchPolicy(max_batch=max_batch, max_wait_ms=max_wait_ms,
                           max_queue=max(1024, 2 * n_jobs)),
        autostart=False)
    t0 = time.monotonic()
    futures = [driver.submit(spec, x) for spec, x in jobs]
    driver.start()
    results = [f.result() for f in futures]
    wall = time.monotonic() - t0
    metrics = driver.metrics()
    driver.close()

    verified = None
    if verify:
        for (spec, x), y in zip(jobs, results):
            want = tuned_apply(spec, x, cache=cache)
            np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                       rtol=2e-5, atol=2e-5)
        verified = True

    points = sum(int(np.prod(x.shape)) for _, x in jobs)
    payload = {
        "schema": SCHEMA,
        "version": VERSION,
        "generated_unix": round(time.time(), 1),
        "env": {"backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "python": platform.python_version(),
                "jax": jax.__version__},
        "config": {"n_jobs": n_jobs, "base_size": base,
                   "n_specs": len(specs), "max_batch": max_batch,
                   "max_wait_ms": max_wait_ms, "mode": mode,
                   "padding": padding, "seed": seed},
        "throughput": {"wall_s": round(wall, 4),
                       "jobs_per_s": round(n_jobs / wall, 2),
                       "points_per_s": round(points / wall, 1)},
        "batch_occupancy": metrics["overall"]["batch_occupancy"],
        "latency_ms": {"p50": metrics["overall"]["latency"]["p50_ms"],
                       "p99": metrics["overall"]["latency"]["p99_ms"]},
        "per_plan": metrics["plans"],
        "tuner": metrics["tuner"],
        "speedup_vs_default": _speedups(specs, cache, base, rng, iters),
        "verified_against_tuned_apply": verified,
    }
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
    return payload


def main(argv=None, out="BENCH_serving.json", quick=False):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--size", type=int, default=None,
                    help="2-D edge length ceiling (1-D uses size^2 points)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--mode", choices=("time", "cost"), default=None,
                    help="plan selection (default: cost in --quick, else time)")
    ap.add_argument("--padding", choices=("bucket", "max", "exact"),
                    default="bucket")
    ap.add_argument("--quick", action="store_true",
                    help="small CI profile (fewer jobs, cost-model plans)")
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--out", default=out)
    args = ap.parse_args(argv)

    quick = quick or args.quick
    n_jobs = args.jobs or (40 if quick else 120)
    base = args.size or (32 if quick else 96)
    mode = args.mode or ("cost" if quick else "time")
    payload = run(n_jobs=n_jobs, base=base, max_batch=args.max_batch,
                  max_wait_ms=args.max_wait_ms, mode=mode,
                  padding=args.padding, iters=3 if quick else 5,
                  verify=not args.no_verify, out=args.out)

    th, lat = payload["throughput"], payload["latency_ms"]
    print(f"jobs={n_jobs} specs={payload['config']['n_specs']} "
          f"mode={mode} padding={args.padding}")
    print(f"throughput: {th['jobs_per_s']} jobs/s "
          f"({th['points_per_s']:.3g} points/s) in {th['wall_s']}s")
    print(f"occupancy={payload['batch_occupancy']} "
          f"p50={lat['p50']}ms p99={lat['p99']}ms "
          f"plan_hit_rate={payload['tuner']['plan_hit_rate']}")
    for name, s in payload["speedup_vs_default"].items():
        print(f"  {name:12s} {s['plan']:14s} tuned {s['tuned_us']}us vs "
              f"default {s['default_us']}us -> {s['speedup']}x")
    if payload["verified_against_tuned_apply"]:
        print("all driver outputs verified against per-job tuned_apply")
    if args.out:
        print(f"# artifact written to {args.out}")
    return payload


if __name__ == "__main__":
    main()
