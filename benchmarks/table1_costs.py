"""Paper Table 1: computational + memory overheads, Box-2D3R, c=8 tiles.

Reproduces the analytic cost model for the lower bound, TCStencil,
ConvStencil, LoRAStencil and SPTCStencil, and appends this repo's TPU-native
im2col-in-VMEM kernel (beyond-paper row). Values are per output point.
"""
from __future__ import annotations

from repro.core import analysis

PAPER = {          # (MACs, input access, param access) — paper Table 1
    "lower_bound": (49, 3.06, 0.77),
    "tcstencil": (286.72, 17.92, 17.92),
    "convstencil": (104, 13, 13),
    "lorastencil": (144, 4, 12),
    "sptcstencil": (56, 14, 7),
}


def rows(r: int = 3, c: int = 8):
    t = analysis.table1(r=r, c=c)
    out = []
    for name, cost in t.items():
        macs, inp, par = cost.as_tuple()
        ref = PAPER.get(name)
        ok = ""
        if ref:
            ok = "match" if (abs(macs - ref[0]) < 0.5 and
                             abs(inp - ref[1]) < 0.1 and
                             abs(par - ref[2]) < 0.1) else "MISMATCH"
        out.append((name, macs, inp, par, ok))
    return out


def main(csv: bool = True):
    print("# Table 1 — Box-2D3R per-point costs (paper §2.3 / §3.2.3)")
    print("method,macs,input_access,param_access,vs_paper")
    for name, macs, inp, par, ok in rows():
        print(f"{name},{macs:.2f},{inp:.2f},{par:.2f},{ok}")
    s = analysis.sptcstencil(3)
    for rival in ("tcstencil", "convstencil", "lorastencil"):
        ratio = analysis.METHODS[rival](3).macs / s.macs
        print(f"# MAC reduction vs {rival}: {ratio:.2f}x")
    print(f"# TPU im2col occupancy (K-pad): "
          f"{analysis.mxu_k_occupancy(3):.3f} of MXU lanes at K=49")


if __name__ == "__main__":
    main()
