"""Tuner benchmark: tuned-vs-default speedup and plan-cache hit rates.

For every stencil in the paper suite (§4.1) at a given problem size:
tune (timing mode by default), report the chosen plan, the default
``direct``-backend time, the tuned time, and the speedup; then replay
every stencil to demonstrate warm-cache behavior (plan hits, zero new
engine builds).  Optionally persists plans to a JSON file so a second
run of this script tunes nothing at all.

    PYTHONPATH=src python benchmarks/tuner_bench.py --size 512
    PYTHONPATH=src python benchmarks/tuner_bench.py --cost-model   # no timing
    PYTHONPATH=src python benchmarks/tuner_bench.py --cache-file /tmp/plans.json
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.stencil import paper_suite
from repro.tuner import PlanCache, plan_for, tuned_apply
from repro.tuner.plan import Plan
from repro.tuner.search import measure


def _input(spec, size, rng):
    dims = {1: (size * size,), 2: (size, size)}[spec.ndim]
    shape = tuple(s + 2 * spec.radius for s in dims)
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", type=int, default=512,
                    help="2-D edge length (1-D problems use size^2 points)")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--cost-model", action="store_true",
                    help="select plans with the static cost model (no timing)")
    ap.add_argument("--cache-file", default=None,
                    help="JSON plan persistence path (survives restarts)")
    args = ap.parse_args()

    mode = "cost" if args.cost_model else "time"
    cache = PlanCache(path=args.cache_file)
    preloaded = len(cache)
    if preloaded:
        print(f"# loaded {preloaded} persisted plans from {args.cache_file}")
    rng = np.random.default_rng(0)

    print("stencil,plan,default_us,tuned_us,speedup")
    for spec in paper_suite():
        x = _input(spec, args.size, rng)
        plan = plan_for(spec, x.shape, x.dtype, cache=cache, mode=mode,
                        iters=args.iters)
        tuned_eng = cache.engine(spec, plan)
        default_eng = cache.engine(spec, Plan.default(spec))
        td = measure(default_eng, x, iters=args.iters)
        tt = measure(tuned_eng, x, iters=args.iters)
        print(f"{spec.name},{plan.describe()},{td*1e6:.1f},{tt*1e6:.1f},"
              f"{td/tt:.2f}x")

    builds_before = cache.stats.engine_builds
    for spec in paper_suite():            # warm replay: plan + engine hits only
        tuned_apply(spec, _input(spec, args.size, rng), cache=cache)
    assert cache.stats.engine_builds == builds_before, "warm replay re-built!"
    s = cache.stats
    print(f"# warm replay: {len(list(paper_suite()))} applies, "
          f"0 new engine builds")
    print(f"# cache stats: plans={len(cache)} hit_rate={s.plan_hit_rate:.2f} "
          f"tunes={s.tunes} engine_builds={s.engine_builds} "
          f"engine_hits={s.engine_hits}")
    if args.cache_file:
        print(f"# plans persisted to {args.cache_file} — rerun to skip tuning")


if __name__ == "__main__":
    main()
