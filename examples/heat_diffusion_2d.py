"""End-to-end driver of the paper's kind: iterative 2-D stencil computation
(heat diffusion), run through the SPTCStencil execution path.

Solves u_t = alpha * laplacian(u) with an explicit Star-2D1R update on a
512x512 grid for 400 time steps, comparing the sparse-tensor-core execution
path and the autotuned plan (repro.tuner) against the direct oracle, and
reporting GStencils/s (the paper's metric).

    PYTHONPATH=src python examples/heat_diffusion_2d.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import StencilEngine
from repro.core.stencil import StencilSpec
from repro.tuner import cache_stats, tuned_engine

N, STEPS, ALPHA = 512, 400, 0.2

# explicit heat update: u += alpha * (sum 4-neighbours - 4u)
w = np.zeros((3, 3))
w[0, 1] = w[2, 1] = w[1, 0] = w[1, 2] = ALPHA
w[1, 1] = 1 - 4 * ALPHA
spec = StencilSpec(shape="star", ndim=2, radius=1, weights=w)

# hot square in a cold plate
u0 = np.zeros((N, N), np.float32)
u0[N // 4:N // 2, N // 4:N // 2] = 100.0
u0 = jnp.asarray(np.pad(u0, 1))

for backend in ("direct", "sptc", "tuned"):
    if backend == "tuned":
        # measured plan selection, cached across calls (and across processes
        # when REPRO_TUNER_CACHE is set)
        eng = tuned_engine(spec, u0.shape, u0.dtype)
        print(f"tuner picked backend={eng.backend} L={eng.L} "
              f"(stats: {cache_stats()})")
    else:
        eng = StencilEngine(spec, backend=backend)
    u = eng.iterate(u0, steps=1)            # warm up compile
    jax.block_until_ready(u)
    t0 = time.perf_counter()
    u = eng.iterate(u0, steps=STEPS)
    jax.block_until_ready(u)
    dt = time.perf_counter() - t0
    rate = N * N * STEPS / dt / 1e9
    total = float(jnp.sum(u))
    print(f"{backend:8s}: {dt:6.2f}s  {rate:6.3f} GStencils/s  "
          f"sum(u)={total:.1f}")
    if backend == "direct":
        ref = u
    else:
        err = float(jnp.max(jnp.abs(u - ref)))
        print(f"{'':8s}  max|{backend} - direct| after {STEPS} steps = {err:.2e}")
        assert err < 1e-2, f"{backend} path diverged from oracle"

# heat is conserved up to the insulated-boundary loss
print("heat diffusion OK")
