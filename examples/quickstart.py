"""Quickstart: the paper's technique in ~40 lines.

A Box-2D3R stencil is transformed into banded kernel matrices, strided-swap
permuted into 2:4 structured sparsity, encoded into the SpTC compressed
(values, metadata) form, and executed — all backends agree bit-tight.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (apply_stencil, kernel_matrix, make_stencil,
                        sparsify_stencil_kernel)
from repro.core.sparsify import is_24_sparse, apply_col_perm

# 1. a Box-2D stencil of radius 3 (the paper's headline configuration)
spec = make_stencil("box", 2, 3, seed=42)
print(f"stencil: {spec.name}, {spec.taps} taps")

# 2. one row of the kernel -> banded matrix K (L x 2L), L = 2r+2
row = spec.weights[3]                       # center row, shape (7,)
K = kernel_matrix(row)                      # (8, 16) band, 50% dense
print(f"kernel matrix: {K.shape}, density {np.mean(K != 0):.2f}")

# 3. strided swap -> valid 2:4 pattern -> compressed (values, metadata)
sk = sparsify_stencil_kernel(row)
Kp = apply_col_perm(K, sk.perm)
print(f"2:4 sparse after swap: {is_24_sparse(Kp)}")
print(f"compressed operand: {sk.values.shape} (was {K.shape}) — "
      f"half the reduction width")
print(f"metadata sample (row 0): {sk.meta[0][:8].tolist()}")

# 4. execute the full 2-D stencil through each backend
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(128 + 6, 128 + 6)).astype(np.float32))
y_direct = apply_stencil(spec, x, backend="direct")    # pointwise oracle
y_gemm = apply_stencil(spec, x, backend="gemm")        # dense TC analogue
y_sptc = apply_stencil(spec, x, backend="sptc")        # the paper's method

print(f"gemm  vs direct: max|err| = "
      f"{float(jnp.max(jnp.abs(y_gemm - y_direct))):.2e}")
print(f"sptc  vs direct: max|err| = "
      f"{float(jnp.max(jnp.abs(y_sptc - y_direct))):.2e}")
print("quickstart OK")
