"""Batched serving example: prefill a batch of prompts, then decode
position-aligned batches with ring KV caches — the serving path the
decode_32k / long_500k dry-run cells lower at production scale.

Runs three families to show the cache taxonomy:
  qwen3   (dense)  full-attention ring cache
  mamba2  (ssm)    O(1) state, no KV at all
  mixtral (moe)    sliding-window ring (bounded long-context decode)

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models.nn import count_params
from repro.serving import engine as E


def run(arch: str, batch=4, prompt_len=24, new_tokens=16):
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 0, cfg.vocab)
    cache_len = prompt_len + new_tokens

    t0 = time.perf_counter()
    toks, cc = E.generate(params, cfg, prompt, n_new=new_tokens,
                          cache_len=cache_len)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    cache_mb = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(cc)) / 1e6
    print(f"{arch:22s} family={cfg.family:7s} "
          f"params={count_params(params):>10,} "
          f"cache={cache_mb:7.2f}MB  "
          f"{batch}x{new_tokens} tokens in {dt:5.2f}s  "
          f"sample={toks[0, :6].tolist()}")


if __name__ == "__main__":
    for arch in ("qwen3-1.7b", "mamba2-2.7b", "mixtral-8x22b"):
        run(arch)
    print("batched serving OK")
