"""Stencil-serving example: many users, small grids, one driver.

Simulates a wave of concurrent clients each submitting one modest grid
(different specs, jittered shapes, mixed dtypes) to a shared
`repro.serving.StencilDriver`.  The driver buckets jobs by tuner plan
key, pads near-miss shapes to the bucket, executes super-batches
through `tuned_apply_batched`, and streams results back via futures —
then prints the per-plan admission metrics (occupancy, padding
efficiency, p50/p99) and tuner cache hit rates.

    PYTHONPATH=src python examples/serve_stencils.py
"""
import threading

import jax.numpy as jnp
import numpy as np

from repro.core.stencil import make_stencil
from repro.serving import BatchPolicy, StencilDriver

N_CLIENTS = 8
JOBS_PER_CLIENT = 6


def client(driver, specs, seed, results):
    rng = np.random.default_rng(seed)
    futures = []
    for i in range(JOBS_PER_CLIENT):
        spec = specs[int(rng.integers(len(specs)))]
        dims = ((int(rng.integers(24, 49)), int(rng.integers(24, 49)))
                if spec.ndim == 2 else (int(rng.integers(100, 257)),))
        shape = tuple(s + 2 * spec.radius for s in dims)
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        futures.append(driver.submit(spec, x))
    results[seed] = [f.result() for f in futures]


def main():
    specs = [make_stencil("star", 2, 1, seed=1),
             make_stencil("box", 2, 2, seed=2),
             make_stencil("box", 1, 1, seed=3)]
    results = {}
    with StencilDriver(policy=BatchPolicy(max_batch=16, max_wait_ms=10.0),
                       mode="cost") as driver:
        threads = [threading.Thread(target=client,
                                    args=(driver, specs, s, results))
                   for s in range(N_CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        metrics = driver.metrics()

    done = sum(len(v) for v in results.values())
    o = metrics["overall"]
    print(f"served {done} jobs from {N_CLIENTS} clients in "
          f"{o['batches']} super-batches (occupancy {o['batch_occupancy']})")
    print(f"latency p50={o['latency']['p50_ms']:.0f}ms "
          f"p99={o['latency']['p99_ms']:.0f}ms")
    for key, m in sorted(metrics["plans"].items()):
        print(f"  {key[:54]:54s} jobs={m['completed']:3d} "
              f"occ={m['batch_occupancy']:5.2f} "
              f"pad_eff={m['padding_efficiency']:.2f}")
    t = metrics["tuner"]
    print(f"tuner: plans hit rate {t['plan_hit_rate']}, "
          f"{t['tunes']} tunes, {t['engine_builds']} engine builds")
    print("stencil serving OK")


if __name__ == "__main__":
    main()
