"""End-to-end LM training driver: data pipeline -> sharded train_step ->
atomic checkpoints -> restart-resume, on the host mesh.

Default is a CPU-feasible reduced qwen3-family model (~5M params, a few
hundred steps, visible loss descent on the synthetic Zipf/ngram stream).
The SAME driver trains the full assigned configs on a TPU pod by dropping
--smoke (the dry-run proves those graphs compile on the production mesh).

    PYTHONPATH=src python examples/train_lm.py            # ~5 min on CPU
    PYTHONPATH=src python examples/train_lm.py --steps 50 # quick look
"""
import argparse
import sys

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    argv = ["--arch", "qwen3-1.7b", "--smoke",
            "--steps", str(args.steps),
            "--batch", "8", "--seq", "64", "--lr", "3e-3",
            "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "20"]
    T.main(argv)


if __name__ == "__main__":
    main()
