from repro.configs.base import ModelConfig, ShapeCell, SHAPE_CELLS
from repro.configs.registry import ARCHS, get_config, input_specs, iter_cells
__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS", "ARCHS", "get_config",
           "input_specs", "iter_cells"]
