"""Model configuration schema + the assigned shape cells."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                       # 0 -> d_model // n_heads
    act: str = "swiglu"                   # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0            # chatglm3 2d-RoPE: 0.5
    pos_emb: str = "rope"                 # rope | learned
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # §Perf knob: dtype of the one-hot dispatch/combine tensors — fp32 is
    # the faithful GShard baseline; bf16 halves the dominant MoE temp.
    moe_dispatch_dtype: str = "float32"
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (Zamba2): a shared attention block every ``attn_every`` layers
    attn_every: int = 0
    # enc-dec (Whisper)
    n_enc_layers: int = 0
    n_frames: int = 1500
    # VLM (Llama-3.2-vision): gated cross-attn layer every ``cross_attn_every``
    cross_attn_every: int = 0
    n_img_tokens: int = 0
    # numerics / execution
    norm: str = "rms"                     # rms | ln
    moe_group: int = 512                  # tokens per MoE dispatch group
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    use_pallas: bool = False              # Pallas kernels in-graph (tests/bench)
    max_seq: int = 8192                   # learned-pos table length (static)
    remat: bool = True                    # activation checkpointing per layer
    # 'full' (save layer inputs only) is the baseline: 'dots' keeps fp32
    # attention dot outputs alive across the layer scan -> 81 GB/device on
    # qwen3 train_4k vs 6 GB under 'full' (EXPERIMENTS.md §Perf baseline).
    remat_policy: str = "full"            # dots | full | none
    attn_block_kv: int = 1024             # flash KV block
    decode_window: Optional[int] = None   # ring-cache override (serving)
    # §Perf optimization: banded SWA attention (skip out-of-window KV
    # blocks entirely). False = paper-era blocked/flash baseline.
    banded_attention: bool = False
    attn_block_q: int = 512               # banded path query chunk

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"family {self.family} not in {FAMILIES}")
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:             # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPE_BY_NAME = {c.name: c for c in SHAPE_CELLS}

# long_500k needs sub-quadratic attention: SSM/hybrid families qualify, and
# SWA archs (bounded KV). Pure full-attention archs are skipped (DESIGN.md §4).
LONG_CONTEXT_OK = ("mamba2-2.7b", "zamba2-2.7b", "starcoder2-7b",
                   "mixtral-8x22b")


def cell_applicable(arch: str, cell: ShapeCell, family: str) -> bool:
    if cell.name == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
