"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2d-RoPE (rotates half the head dim), GQA.
[arXiv:2406.12793; hf]
"""
from repro.configs.base import ModelConfig

ARCH = "chatglm3-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=65024,
        rope_fraction=0.5,                  # ChatGLM 2d-RoPE
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        rope_fraction=0.5,
        max_seq=128, remat=False, dtype="float32",
    )
