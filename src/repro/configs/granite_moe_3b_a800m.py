"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Note: the assignment line reads "MoE 40e top-8 — 32 experts top-8"; we take
the explicit shape spec (40 experts, top-8). d_ff=512 is the per-expert FFN.
"""
from repro.configs.base import ModelConfig

ARCH = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155,
        n_experts=40, top_k=8, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=256,
        n_experts=8, top_k=2, moe_group=64, tie_embeddings=True,
        capacity_factor=8.0,            # drop-free: decode==forward exactly
        max_seq=128, remat=False, dtype="float32",
    )
