"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, gated cross-attn image layers every 5 blocks.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, n_img_tokens, d_model) consumed by the
gated cross-attention layers.
"""
from repro.configs.base import ModelConfig

ARCH = "llama-3.2-vision-11b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256,
        rope_theta=500_000.0,
        cross_attn_every=5, n_img_tokens=1600,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        cross_attn_every=2, n_img_tokens=8,
        max_seq=128, remat=False, dtype="float32",
    )
