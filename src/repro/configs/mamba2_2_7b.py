"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

Technique host: the depthwise causal conv1d inside every SSD block is a
per-channel 1-D stencil and runs through kernels/conv1d (use_pallas=True),
the framework integration point of the paper's transform (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

ARCH = "mamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, conv_width=4,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=256,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, conv_width=4,
        ssm_chunk=16, tie_embeddings=True,
        max_seq=128, remat=False, dtype="float32",
    )
