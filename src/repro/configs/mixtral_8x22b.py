"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

The largest assigned config (~141B params). SWA (4096) bounds the decode
ring cache — qualifies for long_500k (DESIGN.md §4). Runs FSDP+TP+EP.
"""
from repro.configs.base import ModelConfig

ARCH = "mixtral-8x22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=32768,
        n_experts=8, top_k=2, sliding_window=4096,
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=256,
        n_experts=4, top_k=2, moe_group=64, sliding_window=16,
        capacity_factor=8.0,            # drop-free: decode==forward exactly
        max_seq=128, remat=False, dtype="float32",
    )
