"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (kv=32, i.e. MHA)
d_ff=8192 vocab=32064 — RoPE, SwiGLU. [arXiv:2404.14219; unverified]
"""
from repro.configs.base import ModelConfig

ARCH = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        max_seq=128, remat=False, dtype="float32",
    )
