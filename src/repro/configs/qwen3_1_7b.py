"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk-norm, GQA, tied embeddings. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig

ARCH = "qwen3-1.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=6144, vocab=151936,
        qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        qk_norm=True, tie_embeddings=True,
        max_seq=128, remat=False, dtype="float32",
    )
