"""Architecture registry: --arch <id> -> ModelConfig (full or smoke), plus
ShapeDtypeStruct input specs for every (arch x shape-cell) dry-run cell."""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ShapeCell, SHAPE_CELLS,
                                cell_applicable)

_MODULES: Dict[str, str] = {
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choices: {ARCHS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.smoke() if smoke else mod.config()


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train    -> tokens (B, S+1)  (loss shifts internally)  [+ memory stub]
    prefill  -> tokens (B, S)                               [+ memory stub]
    decode   -> token (B, 1) + cache pytree (serve_step)
    """
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s + 1), i32)}
    elif cell.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode
        from repro.serving.cache import init_cache
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
        return {"token": jax.ShapeDtypeStruct((b, 1), i32), "cache": cache}
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "vlm":
        specs["memory"] = jax.ShapeDtypeStruct((b, cfg.n_img_tokens, cfg.d_model), dt)
    elif cfg.family == "encdec":
        specs["memory"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), dt)
    return specs


def iter_cells(arch: str):
    """Applicable (cell, skip_reason) pairs for an arch (DESIGN.md §4)."""
    cfg = get_config(arch)
    for cell in SHAPE_CELLS:
        if cell_applicable(arch, cell, cfg.family):
            yield cell, None
        else:
            yield cell, "long_500k needs sub-quadratic attention; " \
                        "this arch is pure full-attention"
