"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE, sliding-window 4096, LayerNorm + GELU.
[arXiv:2402.19173; hf]

SWA bounds the decode ring cache, which is what qualifies this arch for the
long_500k cell (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

ARCH = "starcoder2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18432, vocab=49152,
        sliding_window=4096, act="gelu", norm="ln",
        rope_theta=1e5,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        sliding_window=16, act="gelu", norm="ln",
        max_seq=128, remat=False, dtype="float32",
    )
