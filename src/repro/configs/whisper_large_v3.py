"""whisper-large-v3 [audio/encdec] — 32L(enc)+32L(dec) d_model=1280 20H
(kv=20, MHA) d_ff=5120 vocab=51866 — encoder-decoder, learned positions,
LayerNorm + GELU. Conv frontend is a STUB per the assignment: input_specs()
provides precomputed frame embeddings (B, 1500, d_model).
[arXiv:2212.04356; unverified]

max_seq=32768 extends the decoder's learned-position table to the assigned
decode_32k cell (the real model stops at 448); long_500k is skipped (full
attention, DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

ARCH = "whisper-large-v3"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="encdec",
        n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20,
        n_kv_heads=20, d_ff=5120, vocab=51866,
        n_frames=1500, pos_emb="learned", act="gelu", norm="ln",
        max_seq=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        n_frames=16, pos_emb="learned", act="gelu", norm="ln",
        max_seq=128, remat=False, dtype="float32",
    )
