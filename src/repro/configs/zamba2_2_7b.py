"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + weight-SHARED attention
blocks applied every 6 layers (9 applications of one block).
[arXiv:2411.15242; hf]

Technique host: the Mamba2 conv1d path (kernels/conv1d), as in mamba2-2.7b.
Simplification vs the released model (noted per DESIGN.md): one shared
transformer block instead of two alternating ones, and no LoRA adapters on
the shared block.
"""
from repro.configs.base import ModelConfig

ARCH = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
        attn_every=6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, conv_width=4,
        ssm_chunk=16, attn_every=2,
        max_seq=128, remat=False, dtype="float32",
    )
