"""Core: the paper's contribution — stencil -> 2:4-sparse GEMM transform."""
from repro.core.stencil import StencilSpec, make_stencil, paper_suite
from repro.core.transform import (kernel_matrix, default_l, decompose_rows,
                                  lower_spec)
from repro.core.sparsify import (Sparse24, SparseStencilKernel, encode_24,
                                 decode_24, is_24_sparse, strided_swap_perm,
                                 sparsify_matrices, sparsify_stencil_kernel)
from repro.core.ir import LoweredPlan
from repro.core.engine import StencilEngine, apply_stencil, apply_1d
from repro.core import analysis, sptc

__all__ = [
    "StencilSpec", "make_stencil", "paper_suite", "kernel_matrix",
    "default_l", "decompose_rows", "lower_spec", "Sparse24",
    "SparseStencilKernel", "encode_24", "decode_24", "is_24_sparse",
    "strided_swap_perm", "sparsify_matrices", "sparsify_stencil_kernel",
    "LoweredPlan", "StencilEngine", "apply_stencil", "apply_1d",
    "analysis", "sptc",
]
