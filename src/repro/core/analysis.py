"""Analytic cost models — paper §2.3 (baselines) and §3.2.3 (SPTCStencil).

All functions return **per-output-point** costs for a Box-2D stencil of
radius ``r`` over an A×B grid updated in c×c tiles, reproducing Table 1
(r=3, c=8, TCStencil L=16):

                 MACs    input-acc   param-acc
  lower bound    49      3.06        0.77
  TCStencil      286.72  17.92       17.92
  ConvStencil    104     13          13
  LoRAStencil    144     4           12
  SPTCStencil    56      14          7

Paper erratum (documented, table-consistent version implemented): §3.2.3
prints SPTCStencil_C with a factor ``256·(r+1)`` = ``128·(2r+2)``; Table 1's
56 MACs/point corresponds to ``128·(2r+1)`` — i.e. one SpMM per kernel *row*
(2r+1 of them), each M=N=8⌈c/8⌉, K=4⌈(2r+c)/4⌉, with SpTC executing K/2.
We implement the table-consistent count.

TPU adaptation accounting (beyond paper): the im2col-in-VMEM MXU kernel
performs exactly the lower-bound MACs (2r+1)² per point; its MXU *occupancy*
waste is the K-padding ratio 128/K (the systolic array contracts 128 lanes a
pass regardless), which is an occupancy — not energy/memory — cost, reported
separately. A banded matrix multiplied as dense GEMM wastes
``(band + M - 1)/band >= 2x`` MACs for any tiling/polyphase scheme (the band
contributes `band` useful MACs of the `band+M-1` contraction width per row);
reaching the MAC lower bound requires the *weights* to be the dense operand —
which is what im2col does, and what SpTC approximates in hardware at 2:4.
"""
from __future__ import annotations

import dataclasses
import math


def _ceil(a: float, b: float) -> int:
    return int(math.ceil(a / b))


@dataclasses.dataclass(frozen=True)
class Cost:
    macs: float           # multiply-adds per output point
    input_access: float   # input elements loaded per output point
    param_access: float   # stencil parameters loaded per output point

    def as_tuple(self) -> tuple:
        return (self.macs, self.input_access, self.param_access)


def lower_bound(r: int, c: int = 8) -> Cost:
    return Cost(
        macs=(2 * r + 1) ** 2,
        input_access=(c + 2 * r) ** 2 / c ** 2,
        param_access=(2 * r + 1) ** 2 / c ** 2,
    )


def tcstencil(r: int, L: int = 16) -> Cost:
    pts = (L - 2 * r) ** 2
    macs = L ** 3 * (2 * r + 1) / pts
    acc = L ** 2 * (2 * r + 1) / pts
    return Cost(macs=macs, input_access=acc, param_access=acc)


def convstencil(r: int, c: int = 8) -> Cost:
    # Updates 8ceil(c/8) x (2r+2) points via two GEMMs of
    # M=8ceil(c/8), N=8ceil((2r+2)/8), K=4ceil((2r+1)^2/4)   (§2.3.1)
    # Per-point normalization: ceil(A/(2c(r+1)))/A -> 1/(2c(r+1)) asymptotically
    per_b_rows = 1.0 / (2 * c * (r + 1))
    macs = 512 * per_b_rows * _ceil(c, 8) * _ceil(r + 1, 4) * _ceil((2 * r + 1) ** 2, 4)
    inp = 64 * _ceil((2 * r + 1) ** 2, 4) * per_b_rows * _ceil(c, 8)
    par = inp * _ceil(r + 1, 4)
    return Cost(macs=macs, input_access=inp, param_access=par)


def lorastencil(r: int, c: int = 8) -> Cost:
    macs = (256 * r / c ** 2) * _ceil(c, 8) * _ceil(2 * r + c, 4) * (
        _ceil(2 * r + c, 8) + _ceil(c, 8))
    inp = (32 / c ** 2) * _ceil(2 * r + c, 4) * _ceil(2 * r + c, 8)
    par = 4 * r / _ceil(r, 4)
    return Cost(macs=macs, input_access=inp, param_access=par)


def sptcstencil(r: int, c: int = 8) -> Cost:
    """Table-1-consistent SPTCStencil cost (see module docstring erratum)."""
    m = 8 * _ceil(c, 8)
    n = 8 * _ceil(c, 8)
    k = 4 * _ceil(2 * r + c, 4)
    rows = 2 * r + 1
    macs = rows * m * n * (k // 2) / c ** 2
    inp = (32 / c ** 2) * rows * _ceil(c, 8) * _ceil(2 * r + c, 4)
    par = (16 / c ** 2) * rows * _ceil(c, 8) * _ceil(2 * r + c, 4)
    return Cost(macs=macs, input_access=inp, param_access=par)


def tpu_im2col(r: int, c: int = 8, mxu_k: int = 128) -> Cost:
    """This repo's TPU-native kernel: lower-bound MACs; K-pad occupancy aside."""
    lb = lower_bound(r, c)
    return Cost(macs=lb.macs, input_access=lb.input_access,
                param_access=(2 * r + 1) ** 2 / c ** 2)


def mxu_k_occupancy(r: int, mxu_k: int = 128) -> float:
    """Fraction of MXU contraction lanes doing useful work for K=(2r+1)^2."""
    k = (2 * r + 1) ** 2
    return k / (mxu_k * _ceil(k, mxu_k))


METHODS = {
    "lower_bound": lower_bound,
    "tcstencil": lambda r, c=8: tcstencil(r),
    "convstencil": convstencil,
    "lorastencil": lorastencil,
    "sptcstencil": sptcstencil,
    "tpu_im2col": tpu_im2col,
}


def table1(r: int = 3, c: int = 8) -> dict:
    """Reproduce Table 1 (+ our TPU kernel row)."""
    return {name: fn(r, c) if name != "tcstencil" else tcstencil(r)
            for name, fn in METHODS.items()}
