"""StencilEngine — applies stencils through interchangeable backends.

Backends (all mathematically equivalent; cross-checked in tests):
  direct      pure-jnp shifted multiply-add — the semantic oracle.
  gemm        dense kernel-matrix GEMM (generalized TCStencil, paper §3.2.1):
              banded (L, 2L) matrix times 2L-row input windows.
  sptc        simulated Sparse Tensor Core execution: strided-swap permuted
              + 2:4-compressed kernel, row-swapped inputs (paper §3.2.2/§3.3).
  pallas_*    Pallas TPU kernels (see repro.kernels), same math.

Input convention: ``x`` carries the halo — shape (N1+2r, ..., Nd+2r) — and
the output is the (N1, ..., Nd) interior update.

d-D stencils decompose by kernel rows into 1-D stencils along the last axis
(paper §3.2.1); star stencils additionally get a per-axis fast path.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsify import sparsify_stencil_kernel
from repro.core.stencil import StencilSpec
from repro.core.transform import (axis_decompose_star, decompose_rows,
                                  default_l, kernel_matrix)

BACKENDS = ("direct", "gemm", "sptc", "pallas_direct", "pallas_mxu",
            "pallas_sptc")


# ---------------------------------------------------------------------------
# 1-D application primitives (stencil axis leading, free axis trailing)
# ---------------------------------------------------------------------------

def _windows(x2d: jnp.ndarray, n_out: int, L: int,
             order: np.ndarray | None = None) -> jnp.ndarray:
    """Overlapping (ntiles, 2L, C) windows of a (rows, C) input.

    Tile t covers outputs [tL, tL+L) and reads input rows [tL, tL+2L).
    Rows are zero-padded so every window is in-bounds; the pad rows only ever
    multiply structurally-zero kernel-matrix columns.

    ``order`` reorders the rows *within* each window by folding the
    permutation into the gather's load addresses (paper §3.3: the input row
    swap is zero-cost — it must not lower to a separate permute/gather op).
    """
    ntiles = -(-n_out // L)
    need = (ntiles + 1) * L
    x2d = jnp.pad(x2d, ((0, max(0, need - x2d.shape[0])), (0, 0)))
    within = np.arange(2 * L) if order is None else np.asarray(order)
    idx = (jnp.arange(ntiles) * L)[:, None] + jnp.asarray(within)[None, :]
    return x2d[idx], ntiles


def _apply_1d_direct(w: np.ndarray, x2d: jnp.ndarray, n_out: int) -> jnp.ndarray:
    taps = w.shape[0]
    acc = jnp.zeros((n_out, x2d.shape[1]), dtype=x2d.dtype)
    for k in range(taps):
        if w[k] != 0:
            acc = acc + jnp.asarray(w[k], dtype=x2d.dtype) * x2d[k:k + n_out]
    return acc


def _apply_1d_gemm(w: np.ndarray, x2d: jnp.ndarray, n_out: int,
                   L: int) -> jnp.ndarray:
    K = jnp.asarray(kernel_matrix(w, L=L, pad_width=True), dtype=x2d.dtype)
    win, ntiles = _windows(x2d, n_out, L)
    y = jnp.einsum("lk,tkc->tlc", K, win,
                   preferred_element_type=jnp.float32).astype(x2d.dtype)
    return y.reshape(ntiles * L, -1)[:n_out]


def _apply_1d_sptc(w: np.ndarray, x2d: jnp.ndarray, n_out: int,
                   L: int) -> jnp.ndarray:
    """Compressed 2:4 SpMM with the row swap folded into load addressing.

    The strided-swap permutation AND the 2-bit metadata gather are both
    static, so they compose into the window gather's index array at trace
    time: the lowered hot path contains exactly ONE gather (the im2col
    window read, same as the dense gemm path) and no stray permute ops —
    the paper's §3.3 zero-runtime-overhead contract, certified ahead of
    time by ``repro.vet``'s lowering analyzer.  Numerically identical to
    ``sptc.sptc_matmul`` over swapped windows (the tier-1 oracle tests).
    """
    sk = sparsify_stencil_kernel(w, L=L)
    # rows[t, m, j] = t*L + perm[4*seg(j) + meta[m, j]]  — all compile-time
    comb = np.asarray(sk.perm)[sk.sparse.gather_indices()]      # (L, K/2)
    ntiles = -(-n_out // L)
    need = (ntiles + 1) * L
    x2d = jnp.pad(x2d, ((0, max(0, need - x2d.shape[0])), (0, 0)))
    rows = (np.arange(ntiles) * L)[:, None, None] + comb[None, :, :]
    xg = x2d[jnp.asarray(rows)]                                 # (T, L, K/2, C)
    values = jnp.asarray(sk.values, dtype=x2d.dtype)
    y = jnp.einsum("mk,tmkc->tmc", values, xg,
                   preferred_element_type=jnp.float32).astype(x2d.dtype)
    return y.reshape(ntiles * L, -1)[:n_out]


def _apply_1d_pallas_mxu(w: np.ndarray, x2d: jnp.ndarray, n_out: int,
                         L: int) -> jnp.ndarray:
    from repro.kernels.stencil_gemm.ops import windows_gemm
    K = jnp.asarray(kernel_matrix(w, L=L, pad_width=True), dtype=x2d.dtype)
    win, ntiles = _windows(x2d, n_out, L)
    y = windows_gemm(K, win)
    return y.reshape(ntiles * L, -1)[:n_out]


def _apply_1d_pallas_sptc(w: np.ndarray, x2d: jnp.ndarray, n_out: int,
                          L: int) -> jnp.ndarray:
    from repro.kernels.sptc_spmm.ops import sptc_spmm_windows
    sk = sparsify_stencil_kernel(w, L=L)
    win, ntiles = _windows(x2d, n_out, L)
    win = win[:, np.asarray(sk.perm), :]          # zero-cost row swap (§3.3)
    y = sptc_spmm_windows(jnp.asarray(sk.values, dtype=x2d.dtype),
                          jnp.asarray(sk.meta), win)
    return y.reshape(ntiles * L, -1)[:n_out]


def apply_1d(w: np.ndarray, x: jnp.ndarray, n_out: int, axis: int,
             backend: str, L: int | None = None) -> jnp.ndarray:
    """Apply a 1-D stencil kernel along ``axis`` of ``x`` (halo included)."""
    r = (w.shape[0] - 1) // 2
    if L is None:
        L = default_l(r)
    x = jnp.moveaxis(x, axis, 0)
    lead, rest = x.shape[0], x.shape[1:]
    x2d = x.reshape(lead, -1)
    if backend == "direct":
        y = _apply_1d_direct(w, x2d, n_out)
    elif backend == "gemm":
        y = _apply_1d_gemm(w, x2d, n_out, L)
    elif backend == "sptc":
        y = _apply_1d_sptc(w, x2d, n_out, L)
    elif backend == "pallas_mxu":
        y = _apply_1d_pallas_mxu(w, x2d, n_out, L)
    elif backend == "pallas_sptc":
        y = _apply_1d_pallas_sptc(w, x2d, n_out, L)
    else:
        raise ValueError(f"unknown 1-D backend {backend}")
    return jnp.moveaxis(y.reshape((n_out,) + rest), 0, axis)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class StencilEngine:
    """Compiled applicator for one StencilSpec."""

    def __init__(self, spec: StencilSpec, backend: str = "direct",
                 L: int | None = None, star_fast_path: bool = True,
                 fuse_rows: bool = False) -> None:
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.spec = spec
        self.backend = backend
        self.L = L if L is not None else default_l(spec.radius)
        self.star_fast_path = star_fast_path and spec.shape == "star"
        # §Perf D: one window-gather + one stacked GEMM for all kernel rows
        self.fuse_rows = fuse_rows
        self._fn = jax.jit(self._build())

    # -- graph builders ----------------------------------------------------
    def _build(self) -> Callable:
        if self.backend == "pallas_direct":
            return self._build_pallas()
        spec, backend, L = self.spec, self.backend, self.L
        r, d = spec.radius, spec.ndim

        if d == 1:
            w = spec.weights

            def fn(x: jnp.ndarray) -> jnp.ndarray:
                n_out = x.shape[0] - 2 * r
                return apply_1d(w, x, n_out, 0, backend, L)
            return fn

        if self.star_fast_path:
            axis_kernels = axis_decompose_star(spec)

            def fn(x: jnp.ndarray) -> jnp.ndarray:
                out_shape = tuple(s - 2 * r for s in x.shape)
                acc = jnp.zeros(out_shape, dtype=x.dtype)
                for axis, wk in enumerate(axis_kernels):
                    sl = tuple(
                        slice(None) if a == axis else slice(r, r + out_shape[a])
                        for a in range(d))
                    acc = acc + apply_1d(wk, x[sl], out_shape[axis], axis,
                                         backend, L)
                return acc
            return fn

        rows = decompose_rows(spec)

        if self.fuse_rows and d == 2 and backend in ("gemm", "sptc"):
            return self._build_fused_2d(rows)

        def fn(x: jnp.ndarray) -> jnp.ndarray:
            out_shape = tuple(s - 2 * r for s in x.shape)
            acc = jnp.zeros(out_shape, dtype=x.dtype)
            for lead, wrow in rows:
                sl = tuple(slice(u, u + out_shape[a])
                           for a, u in enumerate(lead)) + (slice(None),)
                acc = acc + apply_1d(wrow, x[sl], out_shape[-1], d - 1,
                                     backend, L)
            return acc
        return fn

    def _build_fused_2d(self, rows: list) -> Callable:
        """§Perf D optimization: ONE window gather + ONE stacked GEMM for
        all 2r+1 kernel rows of a 2-D stencil (vs 2r+1 of each).

        Every row kernel sees the same last-axis window structure; only the
        leading-axis slice differs. So gather windows of the FULL input
        once, multiply by the (R·L, 2L) concatenation of all row kernel
        matrices (R = #rows), then accumulate each row's result from a
        shifted column slice. Same MACs, ~R× fewer gathers/dispatches and
        one MXU-friendly tall GEMM.
        """
        from repro.core.sparsify import apply_col_perm, strided_swap_perm
        spec, backend, L = self.spec, self.backend, self.L
        r = spec.radius
        R = len(rows)
        perm = strided_swap_perm(L) if backend == "sptc" else None
        mats = []
        for _, wrow in rows:
            Kr = kernel_matrix(wrow, L=L, pad_width=True)
            if perm is not None:
                # the dense equivalent of the 2:4-compressed operand: the
                # fused GEMM computes exactly what R sptc_matmul calls do
                Kr = apply_col_perm(Kr, perm)
            mats.append(Kr)
        K_all = np.concatenate(mats, axis=0)          # (R*L, 2L)
        leads = [int(lead[0]) for lead, _ in rows]

        def fn(x: jnp.ndarray) -> jnp.ndarray:
            h_in = x.shape[0]
            h_out = h_in - 2 * r
            w_out = x.shape[1] - 2 * r
            xt = x.T                                   # (W+2r, H+2r)
            # zero-cost row swap: perm folds into the window gather (§3.3)
            win, ntiles = _windows(xt, w_out, L, order=perm)  # (T, 2L, H+2r)
            Km = jnp.asarray(K_all, dtype=x.dtype)
            y = jnp.einsum("lk,tkc->tlc", Km, win,
                           preferred_element_type=jnp.float32
                           ).astype(x.dtype)           # (T, R*L, H+2r)
            y = y.reshape(ntiles, R, L, h_in)
            yr = y.transpose(1, 0, 2, 3).reshape(R, ntiles * L, h_in)
            acc = jnp.zeros((w_out, h_out), dtype=x.dtype)
            for i, u in enumerate(leads):
                acc = acc + yr[i, :w_out, u:u + h_out]
            return acc.T
        return fn

    def _build_pallas(self) -> Callable:
        from repro.kernels import dispatch as kdispatch
        return kdispatch.build(self.spec, self.backend, self.L)

    # -- public API ----------------------------------------------------------
    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self._fn(x)

    def iterate(self, x: jnp.ndarray, steps: int) -> jnp.ndarray:
        """Iterative (Jacobi-style) application with zero-halo re-padding."""
        r = self.spec.radius
        pad = [(r, r)] * self.spec.ndim

        def body(x_in: jnp.ndarray, _: None) -> tuple:
            y = self._fn(x_in)
            return jnp.pad(y, pad), None

        out, _ = jax.lax.scan(body, x, None, length=steps)
        return out


def apply_stencil(spec: StencilSpec, x: jnp.ndarray, backend: str = "direct",
                  L: int | None = None) -> jnp.ndarray:
    """One-shot functional entry point, engine-cached by stencil content.

    Repeated calls with the same (spec, backend, L) reuse one compiled
    StencilEngine from the process-wide ``repro.tuner`` cache instead of
    re-building and re-jitting — SPIDER's zero-runtime-overhead contract.
    For measured backend/L selection use :func:`repro.tuner.tuned_apply`.
    """
    from repro.tuner.cache import default_cache
    from repro.tuner.plan import Plan
    return default_cache().engine(spec, Plan.default(spec, backend, L))(x)
