"""StencilEngine — a generic interpreter for :class:`~repro.core.ir.LoweredPlan`.

The engine no longer hard-codes the paper's transform: ``transform.lower_spec``
runs the ahead-of-time pipeline (row-decompose → kernel-matrix → strided-swap
2:4 sparsify → gather schedule → backend emit) and returns an explicit,
inspectable ``LoweredPlan``; this module merely *executes* that IR — every
table (kernel matrices, compressed operands, window orders, slot/tap
schedules) is read from the plan, never recomputed here.

Backends (all mathematically equivalent; cross-checked in tests):
  direct      pure-jnp shifted multiply-add — the semantic oracle.
  gemm        dense kernel-matrix GEMM (generalized TCStencil, paper §3.2.1):
              banded (L, 2L) matrix times 2L-row input windows.
  sptc        simulated Sparse Tensor Core execution: strided-swap permuted
              + 2:4-compressed kernel, row-swapped inputs (paper §3.2.2/§3.3).
  pallas_*    Pallas TPU kernels (see repro.kernels), same math.

Two workload classes ride on IR-level attributes:
  * variable coefficients (``coefficients=`` on the engine): per-output-point
    weight values applied through ONE shared 2:4 pattern — the swap
    permutation and gather tables come straight from the plan, computed once.
  * temporal blocking (``temporal_steps=k``): one compiled function applies
    the stencil ``k`` times; the input carries a ``k·r`` halo that shrinks by
    ``r`` per step, and ``iterate`` advances ``k`` steps per scan iteration.

Input convention: ``x`` carries the halo — shape (N1+2kr, ..., Nd+2kr) for a
k-step engine — and the output is the (N1, ..., Nd) interior update.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ir import (BACKENDS, LoweredPlan, RowOp,
                           SegmentGatherSchedule)
from repro.core.sparsify import Sparse24, decode_24, sparsify_stencil_kernel
from repro.core.stencil import StencilSpec
from repro.core.transform import default_l, kernel_matrix, lower_spec

__all__ = ["BACKENDS", "StencilEngine", "apply_stencil", "apply_1d"]

ApplyFn = Callable[[jnp.ndarray], jnp.ndarray]


# ---------------------------------------------------------------------------
# 1-D application primitives (stencil axis leading, free axis trailing).
# Each reads its tables as arguments — the interpreter feeds them from the
# plan; `apply_1d` below builds them ad hoc for the standalone utility path.
# ---------------------------------------------------------------------------

def _windows(x2d: jnp.ndarray, n_out: int, L: int,
             order: Optional[np.ndarray] = None
             ) -> Tuple[jnp.ndarray, int]:
    """Overlapping (ntiles, 2L, C) windows of a (rows, C) input.

    Tile t covers outputs [tL, tL+L) and reads input rows [tL, tL+2L).
    Rows are zero-padded so every window is in-bounds; the pad rows only ever
    multiply structurally-zero kernel-matrix columns.

    ``order`` reorders the rows *within* each window by folding the
    permutation into the gather's load addresses (paper §3.3: the input row
    swap is zero-cost — it must not lower to a separate permute/gather op).
    """
    ntiles = -(-n_out // L)
    need = (ntiles + 1) * L
    x2d = jnp.pad(x2d, ((0, max(0, need - x2d.shape[0])), (0, 0)))
    within = np.arange(2 * L) if order is None else np.asarray(order)
    idx = (jnp.arange(ntiles) * L)[:, None] + jnp.asarray(within)[None, :]
    return x2d[idx], ntiles


def _pad_tiles(x2d: jnp.ndarray, n_out: int, L: int
               ) -> Tuple[jnp.ndarray, int]:
    """Zero-pad the row axis so ``ntiles`` full tile reads are in-bounds."""
    ntiles = -(-n_out // L)
    need = (ntiles + 1) * L
    return jnp.pad(x2d, ((0, max(0, need - x2d.shape[0])), (0, 0))), ntiles


def _op_direct(w: np.ndarray, x2d: jnp.ndarray, n_out: int) -> jnp.ndarray:
    taps = w.shape[0]
    acc = jnp.zeros((n_out, x2d.shape[1]), dtype=x2d.dtype)
    for k in range(taps):
        if w[k] != 0:
            acc = acc + jnp.asarray(w[k], dtype=x2d.dtype) * x2d[k:k + n_out]
    return acc


def _op_gemm(K: np.ndarray, x2d: jnp.ndarray, n_out: int,
             L: int) -> jnp.ndarray:
    Km = jnp.asarray(K, dtype=x2d.dtype)
    win, ntiles = _windows(x2d, n_out, L)
    y = jnp.einsum("lk,tkc->tlc", Km, win,
                   preferred_element_type=jnp.float32).astype(x2d.dtype)
    return y.reshape(ntiles * L, -1)[:n_out]


def _op_sptc(values: np.ndarray, comb: np.ndarray, x2d: jnp.ndarray,
             n_out: int, L: int) -> jnp.ndarray:
    """Compressed 2:4 SpMM with the row swap folded into load addressing.

    ``comb[m, j] = perm[4*seg(j) + meta[m, j]]`` — the plan's gather-schedule
    slots.  The strided-swap permutation AND the 2-bit metadata gather are
    both static, so they compose into the window gather's index array at
    trace time: the lowered hot path contains exactly ONE gather (the im2col
    window read, same as the dense gemm path) and no stray permute ops —
    the paper's §3.3 zero-runtime-overhead contract, certified ahead of
    time by ``repro.vet``'s lowering analyzer.  Numerically identical to
    ``sptc.sptc_matmul`` over swapped windows (the tier-1 oracle tests).
    """
    x2d, ntiles = _pad_tiles(x2d, n_out, L)
    rows = (np.arange(ntiles) * L)[:, None, None] + comb[None, :, :]
    xg = x2d[jnp.asarray(rows)]                                 # (T, L, K/2, C)
    vals = jnp.asarray(values, dtype=x2d.dtype)
    y = jnp.einsum("mk,tmkc->tmc", vals, xg,
                   preferred_element_type=jnp.float32).astype(x2d.dtype)
    return y.reshape(ntiles * L, -1)[:n_out]


def _op_pallas_mxu(K: np.ndarray, x2d: jnp.ndarray, n_out: int,
                   L: int) -> jnp.ndarray:
    from repro.kernels.stencil_gemm.ops import windows_gemm
    Km = jnp.asarray(K, dtype=x2d.dtype)
    win, ntiles = _windows(x2d, n_out, L)
    y = windows_gemm(Km, win)
    return y.reshape(ntiles * L, -1)[:n_out]


def _op_pallas_sptc(operand: Sparse24, perm: np.ndarray, x2d: jnp.ndarray,
                    n_out: int, L: int, star_fast: bool) -> jnp.ndarray:
    """Fused v2: ONE Pallas program — window DMA, in-kernel swap+segment
    gather (from the packed meta_bits), MXU matmul.  Nothing is windowed,
    permuted, or gathered outside the kernel (§3.3 zero runtime overhead;
    certified by ``repro.vet``'s pallas-fused analyzer)."""
    from repro.kernels.sptc_spmm.ops import sptc_spmm_fused
    return sptc_spmm_fused(operand, perm, x2d, n_out=n_out, L=L,
                           star_fast="auto" if star_fast else False)


# ---------------------------------------------------------------------------
# Variable-coefficient values: trace-time constants built from the plan's
# slot/tap schedule — computed once per engine, shared 2:4 pattern.
# ---------------------------------------------------------------------------

def _values_tensor(w2d: np.ndarray, tap_tbl: np.ndarray, ntiles: int,
                   L: int, n_out: int) -> np.ndarray:
    """Per-slot value tensor (T, L, S, C) for one variable-coefficient op.

    ``w2d`` is the op's value slab rearranged output-major, shape
    ``(n_out, C, taps)``; ``tap_tbl`` the plan's (L, S) tap schedule.  Slot
    ``(t, l, s)`` of output row ``i = tL + l`` multiplies ``w2d[i, :,
    tap_tbl[l, s]]`` — zero where the slot is structurally dead (tap -1) or
    the row is tile padding.
    """
    gi = (np.arange(ntiles) * L)[:, None] + np.arange(L)[None, :]   # (T, L)
    valid = gi < n_out
    gi = np.minimum(gi, n_out - 1)
    tap_ok = tap_tbl >= 0
    tap_c = np.where(tap_ok, tap_tbl, 0)
    V = w2d[gi[:, :, None], :, tap_c[None, :, :]]                # (T, L, S, C)
    mask = (tap_ok[None, :, :] & valid[:, :, None])[..., None]
    return np.where(mask, V, np.zeros((), dtype=w2d.dtype))


def _op_var_direct(w2d: np.ndarray, x2d: jnp.ndarray,
                   n_out: int) -> jnp.ndarray:
    taps = w2d.shape[-1]
    acc = jnp.zeros((n_out, x2d.shape[1]), dtype=x2d.dtype)
    for k in range(taps):
        if np.any(w2d[:, :, k]):
            wk = jnp.asarray(w2d[:, :, k], dtype=x2d.dtype)
            acc = acc + wk * x2d[k:k + n_out]
    return acc


def _op_var_gemm(w2d: np.ndarray, gather: SegmentGatherSchedule, operand: int,
                 x2d: jnp.ndarray, n_out: int, L: int) -> jnp.ndarray:
    win, ntiles = _windows(x2d, n_out, L)
    V = _values_tensor(w2d, gather.taps[operand], ntiles, L, n_out)
    y = jnp.einsum("tlsc,tsc->tlc", jnp.asarray(V, dtype=x2d.dtype), win,
                   preferred_element_type=jnp.float32).astype(x2d.dtype)
    return y.reshape(ntiles * L, -1)[:n_out]


def _op_var_sptc(w2d: np.ndarray, gather: SegmentGatherSchedule, operand: int,
                 x2d: jnp.ndarray, n_out: int, L: int) -> jnp.ndarray:
    comb = gather.slots[operand]                  # perm ∘ meta, compile-time
    x2d, ntiles = _pad_tiles(x2d, n_out, L)
    rows = (np.arange(ntiles) * L)[:, None, None] + comb[None, :, :]
    xg = x2d[jnp.asarray(rows)]                                 # (T, L, K/2, C)
    V = _values_tensor(w2d, gather.taps[operand], ntiles, L, n_out)
    y = jnp.einsum("tmsc,tmsc->tmc", jnp.asarray(V, dtype=x2d.dtype), xg,
                   preferred_element_type=jnp.float32).astype(x2d.dtype)
    return y.reshape(ntiles * L, -1)[:n_out]


# ---------------------------------------------------------------------------
# The stage interpreter: LoweredPlan -> traced jnp program.
# ---------------------------------------------------------------------------

def _apply_op(plan: LoweredPlan, op: RowOp, x: jnp.ndarray, n_out: int,
              axis: int) -> jnp.ndarray:
    """Execute one constant-coefficient RowOp from the plan's tables."""
    x = jnp.moveaxis(x, axis, 0)
    rest = x.shape[1:]
    x2d = x.reshape(x.shape[0], -1)
    backend, L, i = plan.emit.backend, plan.L, op.operand
    if backend == "direct":
        y = _op_direct(plan.decompose.kernels[i], x2d, n_out)
    elif backend == "gemm":
        kern = plan.kernel
        assert kern is not None
        y = _op_gemm(kern.matrices[i], x2d, n_out, L)
    elif backend == "sptc":
        sp, gather = plan.sparsify, plan.gather
        assert sp is not None and gather is not None
        y = _op_sptc(sp.operands[i].values, gather.slots[i], x2d, n_out, L)
    elif backend == "pallas_mxu":
        kern = plan.kernel
        assert kern is not None
        y = _op_pallas_mxu(kern.matrices[i], x2d, n_out, L)
    elif backend == "pallas_sptc":
        sp = plan.sparsify
        assert sp is not None
        # the metadata-free banded path is the star decomposition's fast
        # path; box "rows" ops keep the faithful one-hot decompression
        star = plan.decompose.mode in ("single", "star-axis")
        y = _op_pallas_sptc(sp.operands[i], sp.perm, x2d, n_out, L,
                            star_fast=star)
    else:
        raise ValueError(f"unknown 1-D backend {backend}")
    return jnp.moveaxis(y.reshape((n_out,) + rest), 0, axis)


def _op_slice(mode: str, op: RowOp, out_shape: Tuple[int, ...], r: int,
              d: int) -> Tuple[Tuple[slice, ...], int]:
    """(input slice, stencil axis) for one RowOp of a d-D application."""
    if mode == "single":
        return (slice(None),), 0
    if mode == "star-axis":
        sl = tuple(slice(None) if a == op.axis else slice(r, r + out_shape[a])
                   for a in range(d))
        return sl, op.axis
    sl = tuple(slice(u, u + out_shape[a])
               for a, u in enumerate(op.lead)) + (slice(None),)
    return sl, d - 1


def _emit_const(plan: LoweredPlan) -> ApplyFn:
    """Constant-coefficient single/star-axis/rows emission — shape-generic."""
    r, d = plan.spec.radius, plan.spec.ndim
    dec = plan.decompose
    mode = dec.mode

    if mode == "single":
        op0 = dec.ops[0]

        def fn1(x: jnp.ndarray) -> jnp.ndarray:
            n_out = x.shape[0] - 2 * r
            return _apply_op(plan, op0, x, n_out, 0)
        return fn1

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        out_shape = tuple(s - 2 * r for s in x.shape)
        acc = jnp.zeros(out_shape, dtype=x.dtype)
        for op in dec.ops:
            sl, axis = _op_slice(mode, op, out_shape, r, d)
            acc = acc + _apply_op(plan, op, x[sl], out_shape[axis], axis)
        return acc
    return fn


def _emit_fused_2d(plan: LoweredPlan) -> ApplyFn:
    """§Perf D emission: ONE window gather + ONE stacked GEMM for all
    2r+1 kernel rows of a 2-D stencil (vs 2r+1 of each).

    Every row kernel sees the same last-axis window structure; only the
    leading-axis slice differs.  So gather windows of the FULL input once,
    multiply by the (R·L, 2L) concatenation of the plan's per-row operands
    (R = #rows), then accumulate each row's result from a shifted column
    slice.  Same MACs, ~R× fewer gathers/dispatches and one MXU-friendly
    tall GEMM.  On the sptc path the stacked matrix is the dense decode of
    the 2:4-compressed operands — the fused GEMM computes exactly what R
    sptc SpMM calls do — and the strided swap rides the window gather's
    load order (§3.3).
    """
    r, L = plan.spec.radius, plan.L
    dec, sp = plan.decompose, plan.sparsify
    R = len(dec.ops)
    if sp is not None:
        mats = [decode_24(opnd) for opnd in sp.operands]
        order: Optional[np.ndarray] = sp.perm
    else:
        kern = plan.kernel
        assert kern is not None
        mats = [np.asarray(m) for m in kern.matrices]
        order = None
    K_all = np.concatenate(mats, axis=0)          # (R*L, 2L)
    leads = [int(op.lead[0]) for op in dec.ops]

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        h_in = x.shape[0]
        h_out = h_in - 2 * r
        w_out = x.shape[1] - 2 * r
        xt = x.T                                   # (W+2r, H+2r)
        # zero-cost row swap: perm folds into the window gather (§3.3)
        win, ntiles = _windows(xt, w_out, L, order=order)  # (T, 2L, H+2r)
        Km = jnp.asarray(K_all, dtype=x.dtype)
        y = jnp.einsum("lk,tkc->tlc", Km, win,
                       preferred_element_type=jnp.float32
                       ).astype(x.dtype)           # (T, R*L, H+2r)
        y = y.reshape(ntiles, R, L, h_in)
        yr = y.transpose(1, 0, 2, 3).reshape(R, ntiles * L, h_in)
        acc = jnp.zeros((w_out, h_out), dtype=x.dtype)
        for i, u in enumerate(leads):
            acc = acc + yr[i, :w_out, u:u + h_out]
        return acc.T
    return fn


def _var_slab_2d(slab: np.ndarray, axis: int) -> np.ndarray:
    """Rearrange a value slab output-major: (n_out, C, taps) matching the
    (stencil-axis leading, free axis trailing) layout of ``_apply_op``."""
    w = np.moveaxis(slab, axis, 0)
    return np.ascontiguousarray(w.reshape(w.shape[0], -1, slab.shape[-1]))


def _emit_var(plan: LoweredPlan) -> ApplyFn:
    """Variable-coefficient emission — fixed-shape by construction.

    The coefficient field pins the output shape, so every table (including
    the per-slot value tensors) is a trace-time constant; the shared 2:4
    pattern means ONE slot/tap schedule serves every operand.
    """
    r, d = plan.spec.radius, plan.spec.ndim
    dec, gather = plan.decompose, plan.gather
    mode, L = dec.mode, plan.L
    assert dec.coefficients is not None
    out_shape = dec.coefficients[0].shape[:-1]
    in_shape = tuple(s + 2 * r for s in out_shape)
    backend = plan.emit.backend

    per_op: List[Tuple[RowOp, Tuple[slice, ...], int, np.ndarray]] = []
    for op in dec.ops:
        sl, axis = _op_slice(mode, op, out_shape, r, d)
        w2d = _var_slab_2d(dec.coefficients[op.operand], axis)
        per_op.append((op, sl, axis, w2d))

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        if tuple(x.shape) != in_shape:
            raise ValueError(
                f"variable-coefficient engine is fixed-shape: expected "
                f"input {in_shape} (= out {out_shape} + 2r halo), got "
                f"{tuple(x.shape)}")
        acc = jnp.zeros(out_shape, dtype=x.dtype)
        for op, sl, axis, w2d in per_op:
            xs = jnp.moveaxis(x[sl], axis, 0)
            rest = xs.shape[1:]
            x2d = xs.reshape(xs.shape[0], -1)
            n_out = out_shape[axis]
            if backend == "direct":
                y2d = _op_var_direct(w2d, x2d, n_out)
            elif backend == "gemm":
                assert gather is not None
                y2d = _op_var_gemm(w2d, gather, op.operand, x2d, n_out, L)
            elif backend == "sptc":
                assert gather is not None
                y2d = _op_var_sptc(w2d, gather, op.operand, x2d, n_out, L)
            else:
                raise ValueError(
                    f"variable coefficients unsupported on {backend}")
            y = jnp.moveaxis(y2d.reshape((n_out,) + rest), 0, axis)
            acc = acc + y
        return acc
    return fn


def _emit_step(plan: LoweredPlan) -> ApplyFn:
    """One stencil application from the plan's tables (temporal_steps ignored)."""
    if plan.emit.backend == "pallas_direct":
        from repro.kernels import dispatch as kdispatch
        fn: ApplyFn = kdispatch.build(plan.spec, plan.emit.backend, plan.L)
        return fn
    if plan.emit.coefficient_mode == "var":
        return _emit_var(plan)
    if plan.decompose.mode == "fused-rows":
        return _emit_fused_2d(plan)
    return _emit_const(plan)


def emit(plan: LoweredPlan) -> ApplyFn:
    """LoweredPlan -> executable (untraced) function — the interpreter.

    A temporal-blocked plan unrolls ``k`` applications into one program:
    the halo shrinks by ``r`` per step, so a ``k·r``-halo input yields the
    interior update after ``k`` steps — ``k`` dots and one window gather per
    step on the matrix backends, nothing else (§3.3 preserved per step).
    """
    plan.validate()
    step = _emit_step(plan)
    k = plan.emit.temporal_steps
    if k == 1:
        return step

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        for _ in range(k):
            x = step(x)
        return x
    return fn


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class StencilEngine:
    """Compiled applicator for one StencilSpec — lowers, then interprets."""

    def __init__(self, spec: StencilSpec, backend: str = "direct",
                 L: Optional[int] = None, star_fast_path: bool = True,
                 fuse_rows: bool = False, temporal_steps: int = 1,
                 coefficients: Optional[np.ndarray] = None) -> None:
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.plan_ir: LoweredPlan = lower_spec(
            spec, backend=backend, L=L, star_fast_path=star_fast_path,
            fuse_rows=fuse_rows, temporal_steps=temporal_steps,
            coefficients=coefficients)
        self.spec = spec
        self.backend = backend
        self.L = self.plan_ir.L
        self.star_fast_path = star_fast_path and spec.shape == "star"
        # §Perf D: one window-gather + one stacked GEMM for all kernel rows
        self.fuse_rows = fuse_rows
        self.temporal_steps = temporal_steps
        self._fn = jax.jit(emit(self.plan_ir))

    # -- public API ----------------------------------------------------------
    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self._fn(x)

    def iterate(self, x: jnp.ndarray, steps: int) -> jnp.ndarray:
        """Iterative (Jacobi-style) application with zero-halo re-padding.

        A temporal-blocked engine advances ``k`` steps per scan iteration
        (``x`` then carries the ``k·r`` halo); ``steps`` must be a multiple
        of ``k``.
        """
        k = self.temporal_steps
        if steps % k != 0:
            raise ValueError(
                f"steps={steps} must be a multiple of temporal_steps={k}")
        pad = [(k * self.spec.radius,) * 2] * self.spec.ndim

        def body(x_in: jnp.ndarray, _: None) -> Tuple[jnp.ndarray, None]:
            y = self._fn(x_in)
            return jnp.pad(y, pad), None

        out, _ = jax.lax.scan(body, x, None, length=steps // k)
        return out


def apply_stencil(spec: StencilSpec, x: jnp.ndarray, backend: str = "direct",
                  L: Optional[int] = None, temporal_steps: int = 1,
                  coefficients: Optional[np.ndarray] = None) -> jnp.ndarray:
    """One-shot functional entry point, engine-cached by stencil content.

    Repeated calls with the same (spec, backend, L, temporal_steps,
    coefficients) reuse one compiled StencilEngine from the process-wide
    ``repro.tuner`` cache instead of re-building and re-jitting — SPIDER's
    zero-runtime-overhead contract.  For measured backend/L selection use
    :func:`repro.tuner.tuned_apply`.
    """
    from repro.tuner.cache import default_cache
    from repro.tuner.plan import Plan
    plan = Plan.default(spec, backend, L, temporal_steps=temporal_steps)
    return default_cache().engine(spec, plan, coefficients=coefficients)(x)


# ---------------------------------------------------------------------------
# Standalone 1-D utility (kept for callers outside the plan pipeline)
# ---------------------------------------------------------------------------

def apply_1d(w: np.ndarray, x: jnp.ndarray, n_out: int, axis: int,
             backend: str, L: Optional[int] = None) -> jnp.ndarray:
    """Apply a 1-D stencil kernel along ``axis`` of ``x`` (halo included)."""
    r = (w.shape[0] - 1) // 2
    if L is None:
        L = default_l(r)
    x = jnp.moveaxis(x, axis, 0)
    rest = x.shape[1:]
    x2d = x.reshape(x.shape[0], -1)
    if backend == "direct":
        y = _op_direct(np.asarray(w), x2d, n_out)
    elif backend == "gemm":
        y = _op_gemm(kernel_matrix(w, L=L, pad_width=True), x2d, n_out, L)
    elif backend == "sptc":
        sk = sparsify_stencil_kernel(w, L=L)
        comb = np.asarray(sk.perm)[sk.sparse.gather_indices()]
        y = _op_sptc(sk.values, comb, x2d, n_out, L)
    elif backend == "pallas_mxu":
        y = _op_pallas_mxu(kernel_matrix(w, L=L, pad_width=True), x2d,
                           n_out, L)
    elif backend == "pallas_sptc":
        sk = sparsify_stencil_kernel(w, L=L)
        y = _op_pallas_sptc(sk.sparse, sk.perm, x2d, n_out, L,
                            star_fast=True)
    else:
        raise ValueError(f"unknown 1-D backend {backend}")
    return jnp.moveaxis(y.reshape((n_out,) + rest), 0, axis)
