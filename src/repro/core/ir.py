"""Explicit lowering IR: the stencil engine's compilation pipeline as data.

The SPIDER transform (paper §3.2) is a fixed sequence of ahead-of-time
stages — every one of them pure table construction, no kernel execution:

    spec ──► row-decompose ──► kernel-matrix ──► strided-swap ──►
             gather-schedule ──► emit

A :class:`LoweredPlan` records that sequence explicitly, one frozen
dataclass per stage, so that

  * ``core/transform.py`` (:func:`~repro.core.transform.lower_spec`)
    *produces* plans,
  * ``core/engine.py`` merely *executes* them through one generic stage
    interpreter per backend, and
  * ``repro.vet`` *inspects* them — the shared-pattern invariant for
    variable-coefficient kernels and the per-step op budgets for
    temporal blocking are checked on the IR, before anything compiles.

Stage presence depends on the backend: ``direct`` plans stop after
row-decompose; ``gemm``-family plans add the kernel-matrix and gather
stages; ``sptc``-family plans carry all five.

Two workload attributes live at the IR level rather than inside the
stage tables:

  * ``BackendEmit.coefficient_mode`` — ``"var"`` plans apply per-output-
    point weight *values* while every row shares ONE sparsity pattern /
    meta-bits, so the swap permutation and gather tables are computed
    once (``RowDecompose.coefficients`` holds the per-row value slabs).
  * ``BackendEmit.temporal_steps`` — a ``k``-step temporal block: the
    emitted program applies the stencil ``k`` times in one compiled
    function, amortizing the AOT swap tables across steps (the input
    carries a ``k·r`` halo that shrinks by ``r`` per step).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Tuple, Union

import numpy as np

from repro.core.sparsify import Sparse24
from repro.core.stencil import StencilSpec

#: every backend an emitted plan can target (the engine's dispatch set)
BACKENDS: Tuple[str, ...] = ("direct", "gemm", "sptc", "pallas_direct",
                             "pallas_mxu", "pallas_sptc")

#: backends that execute through kernel matrices (stages 2-4 present)
MATRIX_BACKENDS: Tuple[str, ...] = ("gemm", "sptc", "pallas_mxu",
                                    "pallas_sptc")

#: backends that execute the 2:4-compressed operand (stage 3 present)
SPARSE_BACKENDS: Tuple[str, ...] = ("sptc", "pallas_sptc")

DECOMPOSE_MODES: Tuple[str, ...] = ("single", "star-axis", "rows",
                                    "fused-rows")
COEFFICIENT_MODES: Tuple[str, ...] = ("const", "var")


@dataclasses.dataclass(frozen=True)
class RowOp:
    """One 1-D stencil application the emitted program performs.

    ``axis`` is the input axis the 1-D kernel runs along; ``lead`` holds
    the leading-axis slice offsets for ``"rows"``-mode decompositions
    (empty otherwise); ``operand`` indexes this op's tables in the
    downstream stages (kernels, matrices, sparse operands, schedules).
    """

    axis: int
    lead: Tuple[int, ...]
    operand: int


@dataclasses.dataclass(frozen=True)
class RowDecompose:
    """Stage 1 — d-D stencil → ordered 1-D row applications (§3.2.1).

    ``kernels[i]`` is the constant ``(2r+1,)`` kernel of operand ``i``.
    In variable-coefficient mode, ``coefficients[i]`` additionally holds
    operand ``i``'s per-output-point values, shape ``out_shape + (2r+1,)``
    (``kernels`` then records the structural all-ones pattern row).
    """

    mode: str
    ops: Tuple[RowOp, ...]
    kernels: Tuple[np.ndarray, ...]
    coefficients: Optional[Tuple[np.ndarray, ...]] = None

    name: ClassVar[str] = "row-decompose"


@dataclasses.dataclass(frozen=True)
class KernelMatrixBuild:
    """Stage 2 — banded ``(L, 2L)`` kernel matrix per operand (§3.2.1)."""

    L: int
    matrices: Tuple[np.ndarray, ...]

    name: ClassVar[str] = "kernel-matrix"


@dataclasses.dataclass(frozen=True)
class StridedSwapSparsify:
    """Stage 3 — strided-swap column permutation + 2:4 encode (§3.2.2).

    ``perm`` is the single ``(2L,)`` involution shared by every operand;
    ``operands[i]`` is operand ``i``'s compressed ``Sparse24``.
    ``shared_pattern`` is True iff all operands carry identical metadata
    — guaranteed by construction for variable-coefficient plans (the
    invariant ``repro.vet`` re-checks).
    """

    perm: np.ndarray
    operands: Tuple[Sparse24, ...]
    shared_pattern: bool

    name: ClassVar[str] = "strided-swap"


@dataclasses.dataclass(frozen=True)
class SegmentGatherSchedule:
    """Stage 4 — fully static load addressing for the emitted program.

    ``window``   (2L,)  row order of the im2col window gather — identity
                 for dense execution; the strided-swap permutation when
                 the row swap folds into the fused window read (§3.3).
    ``slots[i]`` (L, S) input row *within the window* feeding each output
                 slot of operand ``i`` (S = K/2 compressed, 2L dense).
    ``taps[i]``  (L, S) kernel tap index each slot multiplies, ``-1``
                 where the slot is structurally zero.  Variable-
                 coefficient emission reads per-point values through this
                 table — it is computed once, from the shared pattern.
    """

    window: np.ndarray
    slots: Tuple[np.ndarray, ...]
    taps: Tuple[np.ndarray, ...]

    name: ClassVar[str] = "gather-schedule"


@dataclasses.dataclass(frozen=True)
class BackendEmit:
    """Stage 5 — how the interpreter turns the tables into a program."""

    backend: str
    fuse_rows: bool = False
    temporal_steps: int = 1
    coefficient_mode: str = "const"

    name: ClassVar[str] = "emit"


Stage = Union[RowDecompose, KernelMatrixBuild, StridedSwapSparsify,
              SegmentGatherSchedule, BackendEmit]

#: canonical stage order — plans carry a subsequence of this
STAGE_ORDER: Tuple[str, ...] = (RowDecompose.name, KernelMatrixBuild.name,
                                StridedSwapSparsify.name,
                                SegmentGatherSchedule.name, BackendEmit.name)


def tap_table(slots: np.ndarray, taps: int) -> np.ndarray:
    """Kernel-tap index per (row, slot); -1 where structurally zero.

    Kernel-matrix row ``i`` holds ``K[i, j] = w[j - i]`` inside the band,
    and slot ``(i, s)`` reads original column ``slots[i, s]`` — so the tap
    is the column offset relative to the row, masked to the band.
    """
    rel = slots - np.arange(slots.shape[0])[:, None]
    return np.where((rel >= 0) & (rel < taps), rel, -1).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    """The full lowering of one stencil spec: ordered, inspectable stages."""

    spec: StencilSpec
    L: int
    stages: Tuple[Stage, ...]

    # -- stage accessors -----------------------------------------------------
    def _find(self, cls: type) -> Optional[Stage]:
        for s in self.stages:
            if isinstance(s, cls):
                return s
        return None

    @property
    def decompose(self) -> RowDecompose:
        stage = self._find(RowDecompose)
        assert stage is not None, "every plan starts with row-decompose"
        return stage  # type: ignore[return-value]

    @property
    def kernel(self) -> Optional[KernelMatrixBuild]:
        return self._find(KernelMatrixBuild)  # type: ignore[return-value]

    @property
    def sparsify(self) -> Optional[StridedSwapSparsify]:
        return self._find(StridedSwapSparsify)  # type: ignore[return-value]

    @property
    def gather(self) -> Optional[SegmentGatherSchedule]:
        return self._find(SegmentGatherSchedule)  # type: ignore[return-value]

    @property
    def emit(self) -> BackendEmit:
        stage = self._find(BackendEmit)
        assert stage is not None, "every plan ends with backend emit"
        return stage  # type: ignore[return-value]

    # -- derived structure ---------------------------------------------------
    def stage_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.stages)

    def n_applications(self) -> int:
        """1-D applications (== dots on matrix backends) per *step*."""
        if self.decompose.mode == "fused-rows":
            return 1
        return len(self.decompose.ops)

    def describe(self) -> str:
        """Compact pipeline rendering, e.g.
        ``star-2d1r -> row-decompose[star-axis x2] -> kernel-matrix[L4]
        -> strided-swap[2:4 shared] -> gather-schedule -> emit[sptc]``."""
        parts = [self.spec.name]
        for s in self.stages:
            if isinstance(s, RowDecompose):
                tag = f"[{s.mode} x{len(s.ops)}"
                if s.coefficients is not None:
                    tag += " var"
                parts.append(f"{s.name}{tag}]")
            elif isinstance(s, KernelMatrixBuild):
                parts.append(f"{s.name}[L{s.L}]")
            elif isinstance(s, StridedSwapSparsify):
                shared = " shared" if s.shared_pattern else ""
                parts.append(f"{s.name}[2:4{shared}]")
            elif isinstance(s, BackendEmit):
                tag = s.backend
                if s.fuse_rows:
                    tag += " fused"
                if s.temporal_steps != 1:
                    tag += f" k={s.temporal_steps}"
                parts.append(f"{s.name}[{tag}]")
            else:
                parts.append(s.name)
        return " -> ".join(parts)

    # -- structural validation ----------------------------------------------
    def validate(self) -> None:
        """Raise ValueError on any structural inconsistency between stages.

        This is the cheap, always-on check the engine runs at build time;
        ``repro.vet`` re-derives the deeper algebraic invariants.
        """
        names = self.stage_names()
        order = [STAGE_ORDER.index(n) for n in names]
        if order != sorted(order) or len(set(order)) != len(order):
            raise ValueError(f"stage order {names} violates {STAGE_ORDER}")
        if names[0] != RowDecompose.name or names[-1] != BackendEmit.name:
            raise ValueError(
                f"plan must start with row-decompose and end with emit, "
                f"got {names}")
        dec, emit = self.decompose, self.emit
        if dec.mode not in DECOMPOSE_MODES:
            raise ValueError(f"unknown decompose mode {dec.mode!r}")
        if emit.backend not in BACKENDS:
            raise ValueError(f"unknown backend {emit.backend!r}")
        if emit.coefficient_mode not in COEFFICIENT_MODES:
            raise ValueError(
                f"unknown coefficient mode {emit.coefficient_mode!r}")
        if emit.temporal_steps < 1:
            raise ValueError(
                f"temporal_steps must be >= 1, got {emit.temporal_steps}")
        n_ops = len(dec.kernels)
        bad_ops = [op for op in dec.ops
                   if not 0 <= op.operand < n_ops]
        if bad_ops:
            raise ValueError(f"ops reference missing operands: {bad_ops}")
        if (emit.coefficient_mode == "var") != (dec.coefficients is not None):
            raise ValueError("coefficient slabs present iff mode is 'var'")
        if dec.coefficients is not None and \
                len(dec.coefficients) != n_ops:
            raise ValueError("one coefficient slab required per operand")
        kern = self.kernel
        if kern is not None:
            if len(kern.matrices) != n_ops:
                raise ValueError("one kernel matrix required per operand")
            for i, mat in enumerate(kern.matrices):
                if mat.shape != (kern.L, 2 * kern.L):
                    raise ValueError(
                        f"matrix {i} shape {mat.shape} != "
                        f"({kern.L}, {2 * kern.L})")
        sp = self.sparsify
        if sp is not None:
            if kern is None:
                raise ValueError("strided-swap requires kernel matrices")
            if len(sp.operands) != n_ops:
                raise ValueError("one sparse operand required per operand")
            metas = {op.meta.tobytes() for op in sp.operands}
            if sp.shared_pattern and len(metas) > 1:
                raise ValueError(
                    "shared_pattern set but operand metadata differs")
        gather = self.gather
        if gather is not None:
            if len(gather.slots) != n_ops or len(gather.taps) != n_ops:
                raise ValueError("one gather schedule required per operand")
            for i, (slots, taps) in enumerate(zip(gather.slots, gather.taps)):
                if slots.shape != taps.shape:
                    raise ValueError(
                        f"operand {i}: slots {slots.shape} != taps "
                        f"{taps.shape}")
                if slots.size and (slots.min() < 0
                                   or slots.max() >= 2 * self.L):
                    raise ValueError(
                        f"operand {i}: slot index escapes the 2L window")
        if emit.backend in MATRIX_BACKENDS and emit.backend != "pallas_direct":
            if kern is None or gather is None:
                raise ValueError(
                    f"backend {emit.backend} requires kernel-matrix and "
                    "gather-schedule stages")
        if emit.backend in SPARSE_BACKENDS and sp is None:
            raise ValueError(
                f"backend {emit.backend} requires the strided-swap stage")
        if emit.coefficient_mode == "var" and sp is not None \
                and not sp.shared_pattern:
            raise ValueError(
                "variable-coefficient plans must share one 2:4 pattern")
