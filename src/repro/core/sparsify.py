"""Strided-swap structured sparsification + 2:4 encoding (paper §3.2.2).

Pipeline (Figure 5):
  step 1  choose L = 2r+2 so the banded kernel matrix is exactly 50% dense;
  step 2  *strided swap*: permute columns so every aligned 4-element segment
          of every row holds at most 2 non-zeros (the 2:4 pattern).
          With the width padded to 2L, the permutation is: odd positions
          exchange halves (p <-> p+L for odd p < L); even positions fixed.
  step 3  encode into the SpTC compressed format: a value matrix of width
          K/2 (one zero placeholder per row of a 50%-dense band) plus 2-bit
          positional metadata, two strictly-increasing indices per segment,
          ordered from the least significant position.

Why step 2 works (proved by `tests/test_sparsify.py` over a radius sweep and
by hypothesis over arbitrary banded contents): row ``i`` of the band occupies
columns ``[i, i+2r]``, a contiguous run of length ``2r+1 = L-1``. After the
swap, positions ``p`` in ``[i, i+L-2]`` are non-zero only for even ``p`` (odd
positions there hold columns from the other half, which lie outside the band),
and the displaced odd columns land on odd positions whose even neighbours are
outside the band. Any aligned 4-segment therefore sees at most 2 from the even
class or at most 2 from the odd class, never more than 2 total at a boundary.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def strided_swap_perm(L: int) -> np.ndarray:
    """Column permutation of width 2L: odd positions swap halves.

    perm[p] = source column placed at position p. Involution: perm == argsort(perm).
    """
    if L % 2 != 0:
        raise ValueError("L must be even")
    perm = np.arange(2 * L)
    odd_lo = np.arange(1, L, 2)
    perm[odd_lo] = odd_lo + L
    perm[odd_lo + L] = odd_lo
    return perm


def apply_col_perm(mat: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Place source column perm[p] at position p."""
    return mat[:, perm]


def is_24_sparse(mat: np.ndarray) -> bool:
    """True iff every aligned 4-segment of every row has <= 2 non-zeros."""
    m, k = mat.shape
    if k % 4 != 0:
        raise ValueError("width must be a multiple of 4")
    seg = (mat.reshape(m, k // 4, 4) != 0).sum(axis=-1)
    return bool(np.all(seg <= 2))


@dataclasses.dataclass(frozen=True)
class Sparse24:
    """SpTC-compatible compressed operand (paper §3.2.2 step 3).

    values:  (M, K/2) — non-zeros (plus zero placeholders) per segment.
    meta:    (M, K/2) int8 in [0, 4) — intra-segment position of each value,
             strictly increasing within each segment pair, LSB-first.
    k:       original (padded) reduction width K.
    """

    values: np.ndarray
    meta: np.ndarray
    k: int

    @property
    def m(self) -> int:
        return self.values.shape[0]

    def gather_indices(self) -> np.ndarray:
        """(M, K/2) indices into the K dim: 4*segment + meta."""
        half = self.k // 2
        seg = (np.arange(half) // 2) * 4
        return seg[None, :] + self.meta.astype(np.int64)

    def meta_bits(self) -> np.ndarray:
        """Hardware bit packing: per row, one uint32 per 8 segments.

        Each 2-bit field holds one index, LSB-first within the word — the
        layout mma.sp consumes (paper Fig. 5 'metadata is sorted in increasing
        order starting from the least significant bit within each segment').
        """
        m, half = self.meta.shape
        fields_per_word = 16  # 16 x 2-bit fields
        nwords = -(-half // fields_per_word)
        pad = nwords * fields_per_word - half
        meta = np.pad(self.meta, ((0, 0), (0, pad)))
        words = np.zeros((m, nwords), dtype=np.uint32)
        for f in range(fields_per_word):
            words |= (meta[:, f::fields_per_word].astype(np.uint32) & 0x3) << (2 * f)
        return words


def encode_24(mat: np.ndarray) -> Sparse24:
    """Compress a 2:4-sparse matrix into (values, metadata).

    Deterministic placeholder rule for segments with < 2 non-zeros (indices
    must be strictly increasing):
      0 non-zeros            -> indices (2, 3), values (0, 0)
      1 non-zero at p < 3    -> indices (p, 3), values (v, 0)
      1 non-zero at p == 3   -> indices (2, 3), values (0, v)
    """
    m, k = mat.shape
    if k % 4 != 0:
        raise ValueError("width must be a multiple of 4")
    if not is_24_sparse(mat):
        raise ValueError("matrix is not 2:4 sparse; apply strided swap first")
    nseg = k // 4
    values = np.zeros((m, 2 * nseg), dtype=mat.dtype)
    meta = np.zeros((m, 2 * nseg), dtype=np.int8)
    for i in range(m):
        row = mat[i]
        for s in range(nseg):
            seg = row[4 * s:4 * s + 4]
            nz = np.flatnonzero(seg)
            if len(nz) == 2:
                idx = (int(nz[0]), int(nz[1]))
                val = (seg[nz[0]], seg[nz[1]])
            elif len(nz) == 1:
                p = int(nz[0])
                if p == 3:
                    idx, val = (2, 3), (0, seg[3])
                else:
                    idx, val = (p, 3), (seg[p], 0)
            else:
                idx, val = (2, 3), (0, 0)
            meta[i, 2 * s], meta[i, 2 * s + 1] = idx
            values[i, 2 * s], values[i, 2 * s + 1] = val
    return Sparse24(values=values, meta=meta, k=k)


def decode_24(sp: Sparse24) -> np.ndarray:
    """Reconstruct the dense (permuted) matrix — inverse of encode_24."""
    m = sp.m
    out = np.zeros((m, sp.k), dtype=sp.values.dtype)
    idx = sp.gather_indices()
    np.put_along_axis(out, idx, sp.values, axis=1)
    return out


def contiguous_band_values(sp: Sparse24, perm: np.ndarray) -> "np.ndarray | None":
    """Banded re-layout of a compressed operand, or None if not banded.

    When the composed gather ``comb[m, j] = perm[4*seg(j) + meta[m, j]]``
    is the identity band of the taps — every non-zero slot of row ``m``
    reads input row ``m + off`` with ``0 <= off < K/2`` — the 2:4 pattern
    carries no information beyond the band structure, and the kernel can
    skip the one-hot decompression entirely: it needs only the values
    re-laid-out by offset, ``out[m, off] = values[m, j]``.  This is the
    star-shape fast path (the swap∘meta permutation is the identity on
    the star taps); it holds for every banded (L, 2L) kernel matrix the
    stencil pipeline produces, and fails (returns None) for any operand
    whose pattern escapes the band.
    """
    comb = np.asarray(perm)[sp.gather_indices()]
    m, kh = sp.values.shape
    out = np.zeros_like(np.asarray(sp.values))
    for i in range(m):
        for j in range(kh):
            v = sp.values[i, j]
            if v == 0:
                continue
            off = comb[i, j] - i
            if not 0 <= off < kh:
                return None
            out[i, off] += v
    return out


def sparsify_matrices(mats: "tuple[np.ndarray, ...] | list[np.ndarray]",
                      L: int) -> "tuple[np.ndarray, tuple[Sparse24, ...], bool]":
    """Strided-swap + 2:4-encode a family of (L, 2L) kernel matrices.

    The lowering pipeline's stage-3 producer (see :mod:`repro.core.ir`):
    ONE permutation serves every matrix, each matrix gets its own
    compressed operand, and the returned flag records whether all
    operands share identical metadata (the variable-coefficient
    shared-pattern invariant — trivially true when the matrices share
    one zero structure).
    """
    perm = strided_swap_perm(L)
    operands = []
    for K in mats:
        Kp = apply_col_perm(np.asarray(K), perm)
        if not is_24_sparse(Kp):   # structural guarantee; double-checked
            raise AssertionError("strided swap failed to produce 2:4 pattern")
        operands.append(encode_24(Kp))
    shared = len({op.meta.tobytes() for op in operands}) <= 1
    return perm, tuple(operands), shared


@dataclasses.dataclass(frozen=True)
class SparseStencilKernel:
    """A 1-D stencil kernel fully transformed for SpTC execution.

    Carries the compressed operand, the column permutation (== the input row
    permutation, it is an involution), and bookkeeping for tiling.
    """

    sparse: Sparse24
    perm: np.ndarray           # (2L,) strided-swap involution
    L: int                     # outputs per tile (M of the SpMM)
    radius: int
    window: int                # input rows consumed per tile = 2L (padded)

    @property
    def values(self) -> np.ndarray:
        return self.sparse.values

    @property
    def meta(self) -> np.ndarray:
        return self.sparse.meta


def sparsify_stencil_kernel(w: np.ndarray, L: int | None = None) -> SparseStencilKernel:
    """stencil row -> banded matrix -> strided swap -> 2:4 encode."""
    from repro.core.transform import default_l, kernel_matrix

    w = np.asarray(w)
    r = (w.shape[0] - 1) // 2
    if L is None:
        L = default_l(r)
    K = kernel_matrix(w, L=L, pad_width=True)        # (L, 2L)
    perm = strided_swap_perm(L)
    Kp = apply_col_perm(K, perm)
    if not is_24_sparse(Kp):  # structural guarantee; double-checked anyway
        raise AssertionError("strided swap failed to produce 2:4 pattern")
    return SparseStencilKernel(sparse=encode_24(Kp), perm=perm, L=L,
                               radius=r, window=2 * L)
