"""Simulated Sparse Tensor Core semantics (jnp reference).

``mma.sp`` on NVIDIA Ampere computes, per output row i of the LHS:

    y[i, n] = sum_s sum_t  values[i, 2s+t] * X[4s + meta[i, 2s+t], n]

i.e. for every 4-wide segment of the reduction dim it reads only the 2 rows of
the RHS selected by the 2-bit metadata. TPUs have no such unit; this module is
the *bit-faithful executable semantics* used as the oracle for the Pallas
kernel and for the transformation pipeline's correctness proofs. The MAC count
of the skipped execution (M * K/2 * N) is what `core/analysis.py` charges.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def sptc_matmul(values: jnp.ndarray, meta: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    """Compressed 2:4 SpMM: (M, K/2) x metadata x (K, N) -> (M, N).

    values: (M, K/2) float; meta: (M, K/2) int in [0,4); x: (K, N).
    """
    m, half = values.shape
    k = x.shape[0]
    if half * 2 != k:
        raise ValueError(f"values width {half} != K/2 = {k//2}")
    seg = (jnp.arange(half) // 2) * 4
    gather = seg[None, :] + meta.astype(jnp.int32)        # (M, K/2)
    xg = x[gather]                                        # (M, K/2, N)
    return jnp.einsum("mk,mkn->mn", values.astype(x.dtype), xg,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def sptc_matmul_dense_equiv(values: jnp.ndarray, meta: jnp.ndarray,
                            k: int) -> jnp.ndarray:
    """Decompress (values, meta) to the dense (M, K) permuted matrix (jnp)."""
    m, half = values.shape
    seg = (jnp.arange(half) // 2) * 4
    gather = seg[None, :] + meta.astype(jnp.int32)
    out = jnp.zeros((m, k), dtype=values.dtype)
    rows = jnp.arange(m)[:, None]
    return out.at[rows, gather].add(values)


def swap_rows(x: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """Zero-cost row swap (paper §3.3) — reference form.

    Column-permuting the LHS by ``perm`` requires row-permuting the RHS by the
    same involution for mathematical equivalence. In the Pallas kernels this
    indexing is folded into the load address computation; here it is explicit.
    """
    return x[np.asarray(perm)]
