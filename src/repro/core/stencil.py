"""Stencil problem specification.

A stencil is characterized (paper §2.1) by shape type (star | box),
dimensionality d and radius r. The stencil kernel is the (2r+1)^d weight
array; star stencils have non-zeros only along the axes through the center.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

SHAPES = ("star", "box")


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """Static description of a stencil computation.

    Attributes:
      shape: "star" or "box".
      ndim: spatial dimensionality (1, 2 or 3).
      radius: dependency radius r (order).
      weights: numpy array of shape (2r+1,)*ndim. For star stencils all
        entries off the axis cross are zero.
    """

    shape: str
    ndim: int
    radius: int
    weights: np.ndarray

    def __post_init__(self) -> None:
        if self.shape not in SHAPES:
            raise ValueError(f"shape must be one of {SHAPES}, got {self.shape}")
        if self.ndim not in (1, 2, 3):
            raise ValueError(f"ndim must be 1, 2 or 3, got {self.ndim}")
        if self.radius < 1:
            raise ValueError("radius must be >= 1")
        expect = (2 * self.radius + 1,) * self.ndim
        if tuple(self.weights.shape) != expect:
            raise ValueError(
                f"weights shape {self.weights.shape} != expected {expect}")
        if self.shape == "star" and not _is_star(self.weights, self.radius):
            raise ValueError("weights are not star-shaped")

    @property
    def taps(self) -> int:
        """Number of (potentially) non-zero points in the pattern."""
        if self.shape == "box":
            return (2 * self.radius + 1) ** self.ndim
        return 2 * self.radius * self.ndim + 1

    @property
    def name(self) -> str:
        return f"{self.shape}-{self.ndim}d{self.radius}r"


def _is_star(w: np.ndarray, r: int) -> bool:
    mask = np.zeros_like(w, dtype=bool)
    center = (r,) * w.ndim
    for axis in range(w.ndim):
        idx = list(center)
        idx[axis] = slice(None)
        mask[tuple(idx)] = True
    return bool(np.all(w[~mask] == 0))


def star_mask(ndim: int, radius: int) -> np.ndarray:
    """Boolean mask of the star pattern inside a (2r+1)^d cube."""
    w = np.ones((2 * radius + 1,) * ndim)
    mask = np.zeros_like(w, dtype=bool)
    center = (radius,) * ndim
    for axis in range(ndim):
        idx = list(center)
        idx[axis] = slice(None)
        mask[tuple(idx)] = True
    return mask


def make_stencil(shape: str, ndim: int, radius: int,
                 seed: int | None = 0,
                 weights: np.ndarray | None = None) -> StencilSpec:
    """Construct a stencil with given pattern. Random weights by default.

    Weights are drawn from U(0.1, 1.0) then normalized to sum 1 (a smoothing
    stencil — keeps iterated application numerically stable), matching common
    practice in the stencil benchmark literature (heat/jacobi kernels).
    """
    k = 2 * radius + 1
    if weights is None:
        rng = np.random.default_rng(seed)
        weights = rng.uniform(0.1, 1.0, size=(k,) * ndim)
    weights = np.asarray(weights, dtype=np.float64).copy()
    if shape == "star":
        weights = weights * star_mask(ndim, radius)
    weights = weights / weights.sum()
    return StencilSpec(shape=shape, ndim=ndim, radius=radius, weights=weights)


# The paper's benchmark suite (§4.1): 1D r∈{1,2}; 2D star/box r∈{1,2,3}.
PAPER_SUITE: Tuple[Tuple[str, int, int], ...] = (
    ("box", 1, 1),
    ("box", 1, 2),
    ("star", 2, 1),
    ("star", 2, 2),
    ("star", 2, 3),
    ("box", 2, 1),
    ("box", 2, 2),
    ("box", 2, 3),
)


def paper_suite() -> Tuple[StencilSpec, ...]:
    return tuple(make_stencil(s, d, r, seed=17 * d + r) for s, d, r in PAPER_SUITE)
