"""Stencil -> matrix-multiplication transform (paper §3.2.1).

A 1-D stencil kernel ``w`` of radius ``r`` becomes a banded *kernel matrix*
``K`` of shape ``(L, 2r+L)`` with ``K[i, i+k] = w[k]``: ``Y = K @ X`` computes
``L`` consecutive stencil outputs for every column of ``X`` (the free axis).
Unlike TCStencil, ``K`` is rectangular — no blank rows.

We pad the width to ``2L`` (columns beyond ``2r+L`` are structurally zero) so
the strided-swap permutation (sparsify.py) is an involution on column pairs
``(j, j+L)`` and the 2:4 segment grid divides the width evenly.

Higher-dimensional stencils decompose by kernel rows (paper §3.2.1): a d-D
kernel is a sum over its leading (d-1)-D offsets of 1-D stencils applied along
the last axis; partial results accumulate.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.stencil import StencilSpec


def default_l(radius: int) -> int:
    """Paper's choice: L = 2r+2 — exactly 50% band density (§3.2.2 step 1)."""
    return 2 * radius + 2


def kernel_matrix(w: np.ndarray, L: int | None = None,
                  pad_width: bool = True) -> np.ndarray:
    """Banded kernel matrix for a 1-D stencil kernel ``w`` (length 2r+1).

    Returns shape ``(L, 2L)`` if pad_width else ``(L, 2r+L)``.
    Requires ``L >= 2r+2`` and ``L`` even for 2:4 sparsifiability.
    """
    w = np.asarray(w)
    taps = w.shape[0]
    if taps % 2 != 1:
        raise ValueError("1-D stencil kernel must have odd length 2r+1")
    r = (taps - 1) // 2
    if L is None:
        L = default_l(r)
    if L < 2 * r + 2 or L % 2 != 0:
        raise ValueError(f"need even L >= 2r+2 = {2*r+2}, got {L}")
    width = 2 * L if pad_width else 2 * r + L
    K = np.zeros((L, width), dtype=w.dtype)
    for i in range(L):
        K[i, i:i + taps] = w
    return K


def decompose_rows(spec: StencilSpec) -> List[Tuple[Tuple[int, ...], np.ndarray]]:
    """Decompose a d-D stencil into 1-D row kernels (paper §3.2.1).

    Returns a list of ``(lead_offset, w_1d)`` where ``lead_offset`` indexes the
    leading d-1 axes of the kernel (0-based, i.e. offset - r gives the spatial
    shift) and ``w_1d`` is the (2r+1,) kernel row applied along the last axis.
    All-zero rows (star stencils' off-axis rows) are dropped.
    """
    w = spec.weights
    if spec.ndim == 1:
        return [((), w)]
    lead_shape = w.shape[:-1]
    out: List[Tuple[Tuple[int, ...], np.ndarray]] = []
    for lead in np.ndindex(*lead_shape):
        row = w[lead]
        if np.any(row != 0):
            out.append((lead, row))
    return out


def axis_decompose_star(spec: StencilSpec) -> List[np.ndarray]:
    """Fast path for star stencils: one 1-D kernel per axis.

    The center tap is kept in the *last*-axis kernel and zeroed in the others
    so that summing the per-axis 1-D applications counts it exactly once.
    Returns list of per-axis (2r+1,) kernels, index = axis.
    """
    if spec.shape != "star":
        raise ValueError("axis decomposition only applies to star stencils")
    r = spec.radius
    center = (r,) * spec.ndim
    kernels = []
    for axis in range(spec.ndim):
        idx = list(center)
        idx[axis] = slice(None)
        k = np.array(spec.weights[tuple(idx)])
        if axis != spec.ndim - 1:
            k[r] = 0.0
        kernels.append(k)
    return kernels


def band_density(radius: int, L: int) -> float:
    """Non-zero density of the (unpadded) kernel matrix: (2r+1)/(2r+L)."""
    return (2 * radius + 1) / (2 * radius + L)
