"""Stencil -> matrix-multiplication transform and plan lowering (paper §3.2.1).

A 1-D stencil kernel ``w`` of radius ``r`` becomes a banded *kernel matrix*
``K`` of shape ``(L, 2r+L)`` with ``K[i, i+k] = w[k]``: ``Y = K @ X`` computes
``L`` consecutive stencil outputs for every column of ``X`` (the free axis).
Unlike TCStencil, ``K`` is rectangular — no blank rows.

We pad the width to ``2L`` (columns beyond ``2r+L`` are structurally zero) so
the strided-swap permutation (sparsify.py) is an involution on column pairs
``(j, j+L)`` and the 2:4 segment grid divides the width evenly.

Higher-dimensional stencils decompose by kernel rows (paper §3.2.1): a d-D
kernel is a sum over its leading (d-1)-D offsets of 1-D stencils applied along
the last axis; partial results accumulate.

:func:`lower_spec` is the front door: it runs the full ahead-of-time
pipeline — row-decompose → kernel-matrix build → strided-swap sparsify →
segment-gather schedule → backend emit — and returns the explicit
:class:`repro.core.ir.LoweredPlan` that ``core/engine.py`` executes.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Tuple

import numpy as np

from repro.core.stencil import StencilSpec, star_mask

if TYPE_CHECKING:   # pragma: no cover — import cycle guard (ir -> sparsify)
    from repro.core.ir import LoweredPlan


def default_l(radius: int) -> int:
    """Paper's choice: L = 2r+2 — exactly 50% band density (§3.2.2 step 1)."""
    return 2 * radius + 2


def kernel_matrix(w: np.ndarray, L: int | None = None,
                  pad_width: bool = True) -> np.ndarray:
    """Banded kernel matrix for a 1-D stencil kernel ``w`` (length 2r+1).

    Returns shape ``(L, 2L)`` if pad_width else ``(L, 2r+L)``.
    Requires ``L >= 2r+2`` and ``L`` even for 2:4 sparsifiability.
    """
    w = np.asarray(w)
    taps = w.shape[0]
    if taps % 2 != 1:
        raise ValueError("1-D stencil kernel must have odd length 2r+1")
    r = (taps - 1) // 2
    if L is None:
        L = default_l(r)
    if L < 2 * r + 2 or L % 2 != 0:
        raise ValueError(f"need even L >= 2r+2 = {2*r+2}, got {L}")
    width = 2 * L if pad_width else 2 * r + L
    K = np.zeros((L, width), dtype=w.dtype)
    for i in range(L):
        K[i, i:i + taps] = w
    return K


def decompose_rows(spec: StencilSpec) -> List[Tuple[Tuple[int, ...], np.ndarray]]:
    """Decompose a d-D stencil into 1-D row kernels (paper §3.2.1).

    Returns a list of ``(lead_offset, w_1d)`` where ``lead_offset`` indexes the
    leading d-1 axes of the kernel (0-based, i.e. offset - r gives the spatial
    shift) and ``w_1d`` is the (2r+1,) kernel row applied along the last axis.
    All-zero rows (star stencils' off-axis rows) are dropped.
    """
    w = spec.weights
    if spec.ndim == 1:
        return [((), w)]
    lead_shape = w.shape[:-1]
    out: List[Tuple[Tuple[int, ...], np.ndarray]] = []
    for lead in np.ndindex(*lead_shape):
        row = w[lead]
        if np.any(row != 0):
            out.append((lead, row))
    return out


def axis_decompose_star(spec: StencilSpec) -> List[np.ndarray]:
    """Fast path for star stencils: one 1-D kernel per axis.

    The center tap is kept in the *last*-axis kernel and zeroed in the others
    so that summing the per-axis 1-D applications counts it exactly once.
    Returns list of per-axis (2r+1,) kernels, index = axis.
    """
    if spec.shape != "star":
        raise ValueError("axis decomposition only applies to star stencils")
    r = spec.radius
    center = (r,) * spec.ndim
    kernels = []
    for axis in range(spec.ndim):
        idx = list(center)
        idx[axis] = slice(None)
        k = np.array(spec.weights[tuple(idx)])
        if axis != spec.ndim - 1:
            k[r] = 0.0
        kernels.append(k)
    return kernels


def band_density(radius: int, L: int) -> float:
    """Non-zero density of the (unpadded) kernel matrix: (2r+1)/(2r+L)."""
    return (2 * radius + 1) / (2 * radius + L)


# --------------------------------------------------------------------------
# Variable coefficients: per-output-point weight values, one shared pattern.
# --------------------------------------------------------------------------

def validate_coefficients(spec: StencilSpec,
                          coefficients: np.ndarray) -> np.ndarray:
    """Check a variable-coefficient field against its spec.

    ``coefficients`` has shape ``out_shape + (2r+1,)*d``: for each output
    point, the full kernel of weights applied there.  Star specs must keep
    the off-axis kernel entries zero (the structural pattern is per-spec,
    not per-point).
    """
    c = np.asarray(coefficients)
    d, r = spec.ndim, spec.radius
    kshape = (2 * r + 1,) * d
    if c.ndim != 2 * d or c.shape[d:] != kshape:
        raise ValueError(
            f"coefficients must have shape out_shape + {kshape}, got "
            f"{c.shape} for a {d}-D radius-{r} spec")
    if any(s < 1 for s in c.shape[:d]):
        raise ValueError("coefficient output shape must be non-empty")
    if spec.shape == "star":
        mask = star_mask(d, r)
        if np.any(c[..., ~mask] != 0):
            raise ValueError(
                "star spec: coefficients must be zero off the axis cross")
    return c


def _axis_coefficient_slabs(spec: StencilSpec,
                            c: np.ndarray) -> List[np.ndarray]:
    """Per-axis value slabs mirroring :func:`axis_decompose_star`.

    Slab ``axis`` has shape ``out_shape + (2r+1,)``; the center tap stays
    only in the last-axis slab so the summed applications count it once.
    """
    r, d = spec.radius, spec.ndim
    center = (r,) * d
    slabs: List[np.ndarray] = []
    for axis in range(d):
        kidx = list(center)
        kidx[axis] = slice(None)
        slab = np.array(c[(Ellipsis,) + tuple(kidx)])
        if axis != d - 1:
            slab[..., r] = 0.0
        slabs.append(slab)
    return slabs


def _row_coefficient_slabs(
        spec: StencilSpec, c: np.ndarray,
) -> List[Tuple[Tuple[int, ...], np.ndarray]]:
    """Per-row value slabs mirroring :func:`decompose_rows`.

    A row is kept when either the spec's constant weights or the
    coefficient field touch it — a row can be structurally present in the
    field even where the template weight happens to be zero.
    """
    lead_shape = spec.weights.shape[:-1]
    out: List[Tuple[Tuple[int, ...], np.ndarray]] = []
    for lead in np.ndindex(*lead_shape):
        slab = c[(Ellipsis,) + lead + (slice(None),)]
        if np.any(slab != 0) or np.any(spec.weights[lead] != 0):
            out.append((lead, np.asarray(slab)))
    return out


# --------------------------------------------------------------------------
# lower_spec: the full AOT pipeline, producing the explicit LoweredPlan.
# --------------------------------------------------------------------------

def lower_spec(spec: StencilSpec, backend: str = "direct",
               L: Optional[int] = None, star_fast_path: bool = True,
               fuse_rows: bool = False, temporal_steps: int = 1,
               coefficients: Optional[np.ndarray] = None) -> "LoweredPlan":
    """Lower a stencil spec into an explicit :class:`LoweredPlan`.

    Runs the paper's ahead-of-time pipeline (§3.2) stage by stage —
    row-decompose, kernel-matrix build, strided-swap 2:4 sparsify,
    segment-gather schedule, backend emit — and returns the ordered IR
    ``core/engine.py`` interprets.  Pure table construction: nothing here
    traces or compiles.

    ``coefficients`` switches the plan to variable-coefficient mode: the
    structural pattern becomes the all-ones band (so every operand shares
    ONE 2:4 pattern / meta-bits and the swap + gather tables are computed
    once) while the per-point values ride along as decompose-stage slabs.
    ``temporal_steps=k`` marks the plan as a fused k-step iterate.
    """
    from repro.core import ir
    from repro.core.sparsify import sparsify_matrices

    if backend not in ir.BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from "
                         f"{ir.BACKENDS}")
    if temporal_steps < 1:
        raise ValueError(f"temporal_steps must be >= 1, got {temporal_steps}")
    r, d = spec.radius, spec.ndim
    taps = 2 * r + 1
    if L is None:
        L = default_l(r)

    var = coefficients is not None
    coeff: Optional[np.ndarray] = None
    if var:
        coeff = validate_coefficients(spec, coefficients)
        if backend not in ("direct", "gemm", "sptc"):
            raise ValueError(
                "variable coefficients support the jnp backends "
                "(direct/gemm/sptc) only")
        if temporal_steps != 1:
            raise ValueError(
                "variable coefficients cannot combine with temporal "
                "blocking: the value field is tied to one output shape")
        if fuse_rows:
            raise ValueError(
                "fuse_rows is a constant-coefficient optimization")

    # -- stage 1: row decomposition -------------------------------------
    ones = np.ones(taps, dtype=np.float64)
    slabs: Optional[List[np.ndarray]] = [] if var else None
    ops: List[ir.RowOp] = []
    kernels: List[np.ndarray] = []
    if d == 1:
        mode = "single"
        ops = [ir.RowOp(axis=0, lead=(), operand=0)]
        kernels = [ones if var else spec.weights]
        if var:
            assert slabs is not None and coeff is not None
            slabs.append(coeff)
    elif star_fast_path and spec.shape == "star":
        mode = "star-axis"
        axis_kernels = axis_decompose_star(spec)
        ops = [ir.RowOp(axis=a, lead=(), operand=a) for a in range(d)]
        kernels = [ones] * d if var else axis_kernels
        if var:
            assert slabs is not None and coeff is not None
            slabs.extend(_axis_coefficient_slabs(spec, coeff))
    elif var:
        mode = "rows"
        assert slabs is not None and coeff is not None
        for i, (lead, slab) in enumerate(
                _row_coefficient_slabs(spec, coeff)):
            ops.append(ir.RowOp(axis=d - 1, lead=lead, operand=i))
            kernels.append(ones)
            slabs.append(slab)
    else:
        mode = "fused-rows" if (fuse_rows and d == 2
                                and backend in ("gemm", "sptc")) else "rows"
        for i, (lead, w_1d) in enumerate(decompose_rows(spec)):
            ops.append(ir.RowOp(axis=d - 1, lead=lead, operand=i))
            kernels.append(w_1d)

    stages: List[ir.Stage] = [ir.RowDecompose(
        mode=mode, ops=tuple(ops), kernels=tuple(kernels),
        coefficients=tuple(slabs) if var else None)]

    # -- stages 2-4: matrices, sparsify, gather schedule ----------------
    if backend in ir.MATRIX_BACKENDS:
        mats = tuple(kernel_matrix(k, L=L, pad_width=True) for k in kernels)
        stages.append(ir.KernelMatrixBuild(L=L, matrices=mats))
        if backend in ir.SPARSE_BACKENDS:
            perm, operands, shared = sparsify_matrices(mats, L)
            stages.append(ir.StridedSwapSparsify(
                perm=perm, operands=operands, shared_pattern=shared))
            window = perm if mode == "fused-rows" else np.arange(2 * L)
            slots = tuple(perm[op.gather_indices()] for op in operands)
        else:
            window = np.arange(2 * L)
            slots = tuple(np.tile(np.arange(2 * L), (L, 1)) for _ in mats)
        stages.append(ir.SegmentGatherSchedule(
            window=window, slots=slots,
            taps=tuple(ir.tap_table(s, taps) for s in slots)))

    stages.append(ir.BackendEmit(
        backend=backend, fuse_rows=(mode == "fused-rows"),
        temporal_steps=temporal_steps,
        coefficient_mode="var" if var else "const"))

    plan = ir.LoweredPlan(spec=spec, L=L, stages=tuple(stages))
    plan.validate()
    return plan
