from repro.distributed.sharding import (ShardingRules, default_rules,
                                        param_shardings, constrain,
                                        use_mesh_rules, spec_for)

__all__ = ["ShardingRules", "default_rules", "param_shardings", "constrain",
           "use_mesh_rules", "spec_for"]
