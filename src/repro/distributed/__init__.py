from repro.distributed.halo import ShardedStencilEngine, grid_mesh
from repro.distributed.sharding import (ShardingRules, active_mesh_rules,
                                        constrain, default_rules,
                                        param_shardings, spec_for,
                                        use_mesh_rules)

__all__ = ["ShardingRules", "default_rules", "param_shardings", "constrain",
           "use_mesh_rules", "active_mesh_rules", "spec_for",
           "ShardedStencilEngine", "grid_mesh"]
