"""Distributed halo-exchange stencil execution (shard_map + ppermute).

Grids that don't fit one device are block-partitioned over a 1-D or 2-D
device mesh; each shard runs the SAME lowered program the single-device
path runs (`core/engine.emit(LoweredPlan)` — the §3.3 zero-overhead
profile holds per shard), and shards exchange width-``k·r`` halos with
``lax.ppermute``:

  * **Both edges per axis, 2 collectives per partitioned axis.** Shard
    ``i`` sends its high edge to ``i+1`` (which receives it as its low
    halo) and its low edge to ``i-1``.  The ``repro.vet`` sharded probe
    certifies exactly 2 collective-permutes per partitioned axis in the
    compiled HLO, and zero all-gathers.

  * **Zero-flux physical boundary for free.** ``ppermute`` fills devices
    that are not a destination of any ``(src, dst)`` pair with zeros —
    exactly the zero-padding convention ``StencilEngine.iterate`` uses
    (``jnp.pad`` re-pad per step), so the outermost shards need no
    special-casing at all.

  * **Compute/communication overlap, structurally.** The local block is
    split into an interior region (computable from resident data alone)
    and rim slabs (need the exchanged halos).  The ``ppermute``s are
    issued *first* and the interior ``emit(plan)`` call consumes only the
    pre-exchange block, so the interior matmuls carry no data dependence
    on the collectives — XLA's latency-hiding scheduler is free to run
    them under the exchange (async collectives on TPU/GPU; on CPU the
    semantics are identical, the overlap is just not observable).

  * **Corner halos ride along.** Axes are exchanged sequentially and the
    second axis sends edges of the *already-extended* array, so diagonal
    neighbours' corner data arrives through two hops — still only 2
    collectives per axis, and box stencils (which read corners) stay
    exact.

  * **Non-divisible grids.** A dim that doesn't divide its mesh axis is
    trailing-padded to the next multiple; a mask built from
    ``lax.axis_index`` zeroes the phantom rows after every step (keeping
    the zero-flux convention exact under ``iterate``) and the output is
    cropped back.

API convention matches :class:`~repro.core.engine.StencilEngine`:
``engine(x)`` consumes a halo-inclusive ``(N+2kr, ...)`` grid and
returns the ``(N, ...)`` interior update; ``iterate(u, steps)`` evolves
a shape-``(N, ...)`` interior grid with zero boundary, keeping all state
device-resident across steps (one scan inside ``shard_map``).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.engine import emit
from repro.core.ir import LoweredPlan
from repro.core.stencil import StencilSpec
from repro.core.transform import lower_spec

__all__ = ["ShardedStencilEngine", "grid_mesh"]


def grid_mesh(parts, devices: Optional[Sequence] = None) -> Mesh:
    """A 1-D/2-D device mesh for grid partitioning (axes ``sp0``, ``sp1``).

    ``parts`` is the per-axis shard count: ``8`` or ``(8,)`` partitions
    grid axis 0 eight ways; ``(4, 2)`` partitions axes 0 and 1.  Uses the
    first ``prod(parts)`` of ``devices`` (default ``jax.devices()``).
    """
    parts = (int(parts),) if isinstance(parts, int) else tuple(
        int(p) for p in parts)
    if not parts or any(p < 1 for p in parts):
        raise ValueError(f"mesh shape must be positive ints, got {parts}")
    need = math.prod(parts)
    devs = list(jax.devices()) if devices is None else list(devices)
    if len(devs) < need:
        raise ValueError(
            f"mesh {parts} needs {need} devices but only {len(devs)} are "
            f"available (CPU runs can force virtual devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    arr = np.asarray(devs[:need], dtype=object).reshape(parts)
    return Mesh(arr, tuple(f"sp{i}" for i in range(len(parts))))


def _take(x: jnp.ndarray, axis: int, start: int, stop: int) -> jnp.ndarray:
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, stop)
    return x[tuple(idx)]


class ShardedStencilEngine:
    """Block-partitioned stencil applicator over a device mesh.

    ``mesh`` may be a :class:`jax.sharding.Mesh` (1 or 2 axes, each
    partitioning one grid axis — by default grid axes ``0, 1`` in mesh
    axis order; override with ``grid_axes``) or an int / tuple of shard
    counts, which is passed to :func:`grid_mesh`.  Mesh axes of extent 1
    are degenerate (no exchange, plain zero padding) and are dropped.

    All plan knobs (``backend``, ``L``, ``fuse_rows``, ``star_fast_path``,
    ``temporal_steps``) mean exactly what they mean on ``StencilEngine``;
    the lowering is shared and untouched.  Variable coefficients are not
    supported (the per-field tables are fixed to the global shape and do
    not decompose over blocks).
    """

    def __init__(self, spec: StencilSpec, mesh, *,
                 backend: str = "direct", L: Optional[int] = None,
                 star_fast_path: bool = True, fuse_rows: bool = False,
                 temporal_steps: int = 1,
                 grid_axes: Optional[Sequence[int]] = None) -> None:
        if isinstance(mesh, (int, tuple, list)):
            mesh = grid_mesh(mesh)
        if len(mesh.axis_names) > spec.ndim:
            raise ValueError(
                f"mesh has {len(mesh.axis_names)} axes but {spec.name} is "
                f"only {spec.ndim}-D")
        axes = (tuple(range(len(mesh.axis_names))) if grid_axes is None
                else tuple(int(a) for a in grid_axes))
        if len(axes) != len(mesh.axis_names):
            raise ValueError(
                f"grid_axes {axes} must name one grid axis per mesh axis "
                f"{mesh.axis_names}")
        if len(set(axes)) != len(axes) or not all(
                0 <= a < spec.ndim for a in axes):
            raise ValueError(
                f"grid_axes {axes} must be distinct axes of a "
                f"{spec.ndim}-D grid")
        self.spec = spec
        self.mesh = mesh
        self.backend = backend
        self.temporal_steps = temporal_steps
        #: width of the exchanged halo: k·r (temporal blocking fuses k
        #: steps per exchange — communication amortizes with k)
        self.halo = temporal_steps * spec.radius
        # grid axis -> (mesh axis name, shard count); extent-1 axes are
        # single-device along that dim and need no exchange
        self._part: Dict[int, Tuple[str, int]] = {
            a: (name, int(mesh.shape[name]))
            for a, name in zip(axes, mesh.axis_names)
            if int(mesh.shape[name]) > 1}
        self.plan_ir: LoweredPlan = lower_spec(
            spec, backend=backend, L=L, star_fast_path=star_fast_path,
            fuse_rows=fuse_rows, temporal_steps=temporal_steps)
        self.L = self.plan_ir.L
        self._step_fn = emit(self.plan_ir)
        entries: list = [None] * spec.ndim
        for a, (name, _) in self._part.items():
            entries[a] = name
        self._pspec = P(*entries)
        self._run = jax.jit(self._run_sharded, static_argnums=1)
        self._fn = jax.jit(self._halo_call)

    @property
    def n_shards(self) -> int:
        """Devices the grid is actually partitioned over."""
        return math.prod(n for _, n in self._part.values()) or 1

    def partition(self) -> Dict[int, int]:
        """Grid axis -> shard count (extent-1 axes omitted)."""
        return {a: n for a, (_, n) in self._part.items()}

    # -- public API ----------------------------------------------------------
    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        """Halo-inclusive ``(N+2kr, ...)`` in, interior ``(N, ...)`` out.

        Matches ``StencilEngine.__call__`` to float tolerance (block-local
        GEMM tiling reassociates the reductions).
        """
        return self._fn(x)

    def step(self, u: jnp.ndarray) -> jnp.ndarray:
        """One fused k-step on an interior grid with zero boundary."""
        return self._run(u, 1)

    def iterate(self, u: jnp.ndarray, steps: int) -> jnp.ndarray:
        """Evolve ``steps`` steps, state staying device-resident.

        Equals ``StencilEngine.iterate(jnp.pad(u, kr), steps)`` center-
        cropped: the zero re-pad per scan iteration there is exactly the
        zero-flux halo the exchange provides here.  ``steps`` must be a
        multiple of ``temporal_steps``.
        """
        k = self.temporal_steps
        if steps % k != 0:
            raise ValueError(
                f"steps={steps} must be a multiple of temporal_steps={k}")
        return self._run(u, steps // k)

    # -- implementation ------------------------------------------------------
    def _halo_call(self, x: jnp.ndarray) -> jnp.ndarray:
        # running the zero-flux step on the full halo-inclusive domain and
        # center-cropping is exact: output point p reads inputs within
        # distance k·r, so every surviving point reads only real values
        y = self._run_sharded(x, 1)
        h = self.halo
        return y[(slice(h, -h),) * self.spec.ndim]

    def _geometry(self, gshape: Tuple[int, ...]):
        """Trailing pads to shard-divisible extents + per-axis block sizes."""
        h = self.halo
        pads = [(0, 0)] * self.spec.ndim
        blocks: Dict[int, int] = {}
        for a, (_, n) in self._part.items():
            np_a = -(-gshape[a] // n) * n
            b = np_a // n
            if b <= 2 * h:
                raise ValueError(
                    f"dim {a} of extent {gshape[a]} over {n} shards gives "
                    f"per-device blocks of {b} rows, but the halo needs "
                    f"blocks > 2·k·r = {2 * h} (radius {self.spec.radius} × "
                    f"temporal_steps {self.temporal_steps}); use fewer "
                    f"shards along this axis or a larger grid")
            pads[a] = (0, np_a - gshape[a])
            blocks[a] = b
        return pads, blocks

    def _local_step(self, gshape: Tuple[int, ...], blocks: Dict[int, int]):
        """Per-shard zero-flux step closure for one global geometry."""
        h = self.halo
        d = self.spec.ndim
        part = self._part
        paxes = sorted(part)
        step = self._step_fn

        def fn(u: jnp.ndarray) -> jnp.ndarray:
            # unpartitioned axes take the physical zero boundary directly
            pads = [(0, 0) if a in part else (h, h) for a in range(d)]
            base = jnp.pad(u, pads)
            # issue every exchange first: 2 ppermutes per partitioned
            # axis.  Later axes send edges of the already-extended array
            # so corner halos arrive through two hops (box stencils read
            # them).  Shards with no sending neighbour receive zeros —
            # the zero-flux physical boundary.
            ext = base
            for a in paxes:
                name, n = part[a]
                fwd = [(i, i + 1) for i in range(n - 1)]
                bwd = [(i + 1, i) for i in range(n - 1)]
                size = ext.shape[a]
                lo = jax.lax.ppermute(_take(ext, a, size - h, size),
                                      name, fwd)
                hi = jax.lax.ppermute(_take(ext, a, 0, h), name, bwd)
                ext = jnp.concatenate([lo, ext, hi], axis=a)
            # interior: reads only the pre-exchange block, so it carries
            # no dependence on the collectives and overlaps the exchange
            y = step(base)
            # rim slabs consume the exchanged halos; ext is sliced so each
            # slab's output is exactly the h-deep face along its axis
            for j in reversed(range(len(paxes))):
                a = paxes[j]
                b = blocks[a]
                sl_lo = [slice(None)] * d
                sl_hi = [slice(None)] * d
                for a2 in paxes[:j]:
                    sl_lo[a2] = sl_hi[a2] = slice(h, blocks[a2] + h)
                sl_lo[a] = slice(0, 3 * h)
                sl_hi[a] = slice(b - h, b + 2 * h)
                y = jnp.concatenate(
                    [step(ext[tuple(sl_lo)]), y, step(ext[tuple(sl_hi)])],
                    axis=a)
            # zero the phantom rows of a non-divisible dim so iterated
            # steps keep reading zero-flux values past the true boundary
            mask = None
            for a in paxes:
                name, n = part[a]
                b = blocks[a]
                if b * n != gshape[a]:
                    gi = jax.lax.axis_index(name) * b + jnp.arange(b)
                    m = (gi < gshape[a]).reshape(
                        (1,) * a + (b,) + (1,) * (d - a - 1))
                    mask = m if mask is None else mask & m
            if mask is not None:
                y = jnp.where(mask, y, jnp.zeros((), dtype=y.dtype))
            return y

        return fn

    def _run_sharded(self, u: jnp.ndarray, nblocks: int) -> jnp.ndarray:
        if u.ndim != self.spec.ndim:
            raise ValueError(
                f"expected a {self.spec.ndim}-D grid for {self.spec.name}, "
                f"got shape {tuple(u.shape)}")
        gshape = tuple(int(s) for s in u.shape)
        pads, blocks = self._geometry(gshape)
        padded = any(p[1] for p in pads)
        up = jnp.pad(u, pads) if padded else u
        local = self._local_step(gshape, blocks)
        if nblocks == 1:
            body = local
        else:
            def body(blk: jnp.ndarray) -> jnp.ndarray:
                out, _ = jax.lax.scan(
                    lambda c, _: (local(c), None), blk, None, length=nblocks)
                return out
        y = shard_map(body, mesh=self.mesh,
                      in_specs=self._pspec, out_specs=self._pspec)(up)
        if padded:
            y = y[tuple(slice(0, s) for s in gshape)]
        return y
