"""Logical-axis sharding rules (MaxText-style) for DP/FSDP/TP/EP/SP.

Model code annotates parameters and activations with *logical* axis names;
this module maps them onto physical mesh axes. A rule set is a dict
``logical_name -> mesh axis | tuple | None``. Separate namespaces for params
and activations: the same model dim (e.g. embed) is FSDP-sharded in storage
but replicated (or TP-sharded) in compute.

Robustness: when a logical dim is not divisible by its mapped mesh-axis
product, or the mesh axis is already consumed by an earlier dim of the same
tensor, the rule silently degrades to replication for that dim — every
(arch x shape x mesh) cell must *lower*, and the roofline table then shows
the cost of any degraded sharding.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    params: Dict[str, Any]
    acts: Dict[str, Any]


def default_rules(fsdp: bool = True, multi_pod: bool = False) -> ShardingRules:
    """DP over (pod, data); TP over model; FSDP params over data; EP over
    model where divisible (divisibility fallback otherwise)."""
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    params = {
        "embed": "data" if fsdp else None,   # ZeRO-3 weight shard
        "vocab": "model",
        "q_heads": "model",
        "kv_heads": "model",
        "head": None,
        "mlp": "model",
        "experts": "model",                  # EP when divisible
        "heads": None,                       # ssm per-head scalars
        "conv": None,
        "layers": None,
        "seq": None,
    }
    acts = {
        "batch": batch_axes,
        "seq": None,                         # flip to "data" for SP
        "embed": None,                       # replicated over model (Megatron)
        "q_heads": "model",
        "kv_heads": "model",
        "head": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "kv_seq": None,
        "group": batch_axes,                 # MoE dispatch groups
    }
    return ShardingRules(params=params, acts=acts)


def sp_rules(fsdp: bool = True, multi_pod: bool = False) -> ShardingRules:
    """Sequence-parallel variant: shards the sequence dim over 'model' for
    the long-context cells (batch too small to fill the mesh)."""
    r = default_rules(fsdp=fsdp, multi_pod=multi_pod)
    acts = dict(r.acts)
    acts["seq"] = "model"
    acts["kv_seq"] = "model"
    return ShardingRules(params=r.params, acts=acts)


# Logical dims allowed to absorb the 'model' axis when the primary TP dim
# (q/kv heads) is not divisible by it — e.g. whisper's 20 heads or GQA
# kv=8 on a 16-way model axis. Sharding d_head instead keeps the KV cache
# and attention weights distributed; GSPMD inserts the head-dim partial-sum.
FALLBACK_TO_MODEL = ("head",)


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             rules: Dict[str, Any], mesh: Mesh,
             head_fallback: bool = False) -> P:
    """Build a PartitionSpec with divisibility + axis-reuse fallback.

    head_fallback: let d_head absorb an unused 'model' axis — ONLY for
    decode graphs (it shrinks replicated KV caches ~16x when kv_heads
    doesn't divide TP), measured HARMFUL for train/prefill (GSPMD inserts
    involuntary-full-remat reshards on the QK^T path; granite train_4k
    collective 10.9s -> 29.2s). See EXPERIMENTS.md §Perf iteration A0.
    """
    used = set()
    parts = []
    for dim, name in zip(shape, axes):
        mapped = rules.get(name) if name else None
        if mapped is None:
            parts.append(None)
            continue
        cand = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        total = int(np.prod([mesh.shape[a] for a in cand])) if cand else 1
        if not cand or total <= 1 or dim % total != 0:
            parts.append(None)
            continue
        used.update(cand)
        parts.append(cand[0] if len(cand) == 1 else cand)
    # second pass: if 'model' went unused, let a fallback dim absorb it
    if head_fallback and "model" in mesh.shape and "model" not in used:
        for i, (dim, name) in enumerate(zip(shape, axes)):
            if (parts[i] is None if i < len(parts) else True) and \
                    name in FALLBACK_TO_MODEL and \
                    dim % mesh.shape["model"] == 0:
                while len(parts) <= i:
                    parts.append(None)
                parts[i] = "model"
                break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(axes: Any, shapes: Any, rules: ShardingRules,
                    mesh: Mesh, head_fallback: bool = False) -> Any:
    """Tree of NamedShardings for a param tree (axes tree + ShapeDtypeStruct
    tree from eval_shape)."""
    return jax.tree.map(
        lambda ax, sd: NamedSharding(
            mesh, spec_for(ax, sd.shape, rules.params, mesh,
                           head_fallback=head_fallback)),
        axes, shapes, is_leaf=lambda x: isinstance(x, tuple))


# -- activation constraints (context-scoped) --------------------------------
#
# The active (mesh, rules) pair is a PROCESS-WIDE default with a
# thread-local override.  It used to be thread-local only, which made
# ``constrain()`` silently degrade to a no-op on any thread other than
# the one that entered ``use_mesh_rules`` — in particular the
# ``BatchScheduler`` worker thread that actually executes serving
# batches, so serving never applied activation shardings at all.
#
# Semantics now: entering ``use_mesh_rules`` installs the pair as the
# process default (visible to worker threads spawned before or after)
# AND as this thread's override.  A thread may nest its own context to
# override locally without disturbing other threads.  Concurrent
# contexts on different threads race on the process default (last one
# in wins; each restores what it saw on exit) — serving installs one
# mesh per process, which is the supported pattern.

_ctx = threading.local()
_process_state: Optional[Tuple[Mesh, ShardingRules]] = None
_process_lock = threading.Lock()


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: ShardingRules,
                   process_default: bool = True):
    """Activate ``(mesh, rules)`` for :func:`constrain`.

    ``process_default=False`` restores the old thread-confined behavior
    (visible only on the entering thread) for callers that genuinely
    want per-thread isolation.
    """
    global _process_state
    prev_local = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    if process_default:
        with _process_lock:
            prev_process = _process_state
            _process_state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev_local
        if process_default:
            with _process_lock:
                _process_state = prev_process


def active_mesh_rules() -> Optional[Tuple[Mesh, ShardingRules]]:
    """The (mesh, rules) ``constrain`` would use on this thread, or None."""
    state = getattr(_ctx, "state", None)
    if state is not None:
        return state
    with _process_lock:
        return _process_state


def constrain(x, axes: Tuple[Optional[str], ...]):
    """with_sharding_constraint by logical names; no-op outside a mesh ctx.

    Sees the thread-local override first, then the process-wide default —
    worker threads (e.g. the serving batch executor) inherit the mesh the
    main thread entered.
    """
    state = active_mesh_rules()
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(axes, x.shape, rules.acts, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
