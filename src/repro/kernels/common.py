"""Shared kernel utilities."""
from __future__ import annotations

import os

import jax

#: env override for interpret-mode resolution: "1" forces interpret=True
#: everywhere (correctness sweeps on any backend), "0" forces compiled
#: Mosaic lowering (only meaningful on a real TPU).
INTERPRET_ENV_VAR = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """Interpret Pallas kernels unless running on a real TPU.

    This container is CPU-only; TPU v5e is the *target*. interpret=True
    executes the kernel body in Python for bit-level validation against the
    ref.py oracles; on TPU the same pallas_call lowers to Mosaic.  The
    ``REPRO_PALLAS_INTERPRET`` env var overrides the device-based default
    in either direction (read at call resolution time, not import time).
    """
    env = os.environ.get(INTERPRET_ENV_VAR, "")
    if env:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# TPU v5e hardware tiling constants (target hardware).
LANES = 128          # minor-most dim of a VREG / MXU edge
SUBLANES = 8         # second-minor dim of a VREG (fp32)
MXU = 128            # systolic array edge
VMEM_BYTES = 128 * 1024 * 1024
