"""Shared kernel utilities."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Interpret Pallas kernels unless running on a real TPU.

    This container is CPU-only; TPU v5e is the *target*. interpret=True
    executes the kernel body in Python for bit-level validation against the
    ref.py oracles; on TPU the same pallas_call lowers to Mosaic.
    """
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# TPU v5e hardware tiling constants (target hardware).
LANES = 128          # minor-most dim of a VREG / MXU edge
SUBLANES = 8         # second-minor dim of a VREG (fp32)
MXU = 128            # systolic array edge
VMEM_BYTES = 128 * 1024 * 1024
