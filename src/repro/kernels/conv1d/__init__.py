from repro.kernels.conv1d.ops import conv1d_causal
from repro.kernels.conv1d.ref import conv1d_causal_ref

__all__ = ["conv1d_causal", "conv1d_causal_ref"]
