"""Pallas TPU kernel: depthwise causal conv1d (framework home of the paper's
technique — the Mamba2/Zamba2 short convolution is a per-channel 1-D stencil).

Per the stencil engine's taxonomy this is the *batched multi-channel* regime:
the channel axis D provides the wide free dimension, so unlike the single-grid
2-D case both the VPU form (shift-FMA, implemented here) and the GEMM form are
viable on TPU; with K = 4 taps the arithmetic intensity is ~K FLOPs/byte and
the kernel is HBM-bound, so the VPU form is roofline-optimal and the 2:4
machinery would only add MXU occupancy — recorded in DESIGN.md §2.

Grid: (B, ceil(T / bt)). Each step DMAs a (bt + K - 1, D) time-halo block
from HBM into VMEM scratch (causal left halo), then accumulates K shifted
VPU FMAs against the (K, D) tap weights held whole in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _conv_kernel(x_hbm, w_ref, y_ref, scratch, sem, *, k, bt):
    b = pl.program_id(0)
    i = pl.program_id(1)
    cp = pltpu.make_async_copy(
        x_hbm.at[b, pl.ds(i * bt, bt + k - 1), :], scratch, sem)
    cp.start()
    cp.wait()
    acc = jnp.zeros(y_ref.shape[1:], dtype=jnp.float32)
    for j in range(k):                     # static unroll over taps
        acc = acc + w_ref[j][None, :].astype(jnp.float32) * \
            scratch[j:j + bt, :].astype(jnp.float32)
    y_ref[0] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _conv1d_jit(x, w, *, block_t: int, interpret: bool):
    bsz, t, d = x.shape
    k = w.shape[0]
    bt = min(block_t, t)
    nt = -(-t // bt)
    # causal left halo + pad tail so every tile's DMA window is in bounds
    x = jnp.pad(x, ((0, 0), (k - 1, nt * bt - t), (0, 0)))
    y = pl.pallas_call(
        functools.partial(_conv_kernel, k=k, bt=bt),
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((k, d), lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, nt * bt, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt + k - 1, d), x.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(x, w.astype(x.dtype))
    return y[:, :t, :]


def conv1d_causal_call(x, w, *, block_t: int = 256,
                       interpret: bool | None = None):
    """x (B, T, D); w (K, D) -> (B, T, D). D must be lane-padded by caller."""
    if interpret is None:
        interpret = common.default_interpret()
    return _conv1d_jit(x, w, block_t=block_t, interpret=interpret)
