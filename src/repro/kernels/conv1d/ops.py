"""Jitted wrapper: depthwise causal conv1d."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.conv1d.kernel import conv1d_causal_call


def conv1d_causal(x, w, *, block_t: int = 256,
                  interpret: bool | None = None):
    """x (B, T, D); w (K, D) -> (B, T, D)."""
    if interpret is None:
        interpret = common.default_interpret()
    x = jnp.asarray(x)
    w = jnp.asarray(w)
    d = x.shape[-1]
    d_pad = common.round_up(d, common.LANES)
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad - d)))
        w = jnp.pad(w, ((0, 0), (0, d_pad - d)))
    y = conv1d_causal_call(x, w, block_t=block_t, interpret=interpret)
    return y[:, :, :d]
