"""Pure-jnp oracle: depthwise causal conv1d (per-channel 1-D stencil)."""
from __future__ import annotations

import jax.numpy as jnp


def conv1d_causal_ref(x, w):
    """x (B, T, D); w (K, D) -> (B, T, D).

    y[b, t, d] = sum_k w[k, d] * x[b, t - K + 1 + k, d]  (zero history).
    This is the Mamba2/Zamba2 short conv — a radius-(K-1) one-sided 1-D
    stencil applied independently per channel.
    """
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    t = x.shape[1]
    acc = jnp.zeros_like(x)
    for i in range(k):
        acc = acc + w[i][None, None, :] * xp[:, i:i + t, :]
    return acc
