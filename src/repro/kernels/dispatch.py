"""Engine backend -> Pallas kernel builders + backend applicability.

``applicable_backends`` is the tuner's candidate universe: which of
``engine.BACKENDS`` can execute a given spec on a given device kind.
The jnp backends (direct/gemm/sptc) run anywhere XLA does; the Pallas
backends only enter the candidate set on a real TPU (off-TPU they fall
back to interpret mode — bit-faithful but Python-speed, never a winning
plan) unless ``REPRO_TUNER_INCLUDE_PALLAS=1`` forces them in for
correctness sweeps.
"""
from __future__ import annotations

import os
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import StencilSpec

JNP_BACKENDS = ("direct", "gemm", "sptc")
PALLAS_BACKENDS = ("pallas_direct", "pallas_mxu", "pallas_sptc")


def backend_universe(device: str | None = None) -> str:
    """Provenance tag for the candidate universe tuning ran against.

    Recorded in the tuner plan key so plans tuned with the Pallas
    backends forced in (``REPRO_TUNER_INCLUDE_PALLAS=1`` correctness
    sweeps — interpret mode, Python speed) can never be served as
    winning plans to a plain-CPU process, and vice versa.
    """
    device = device if device is not None else jax.default_backend()
    if device == "tpu" or os.environ.get("REPRO_TUNER_INCLUDE_PALLAS") == "1":
        return "jnp+pallas"
    return "jnp"


def applicable_backends(spec: StencilSpec,
                        device: str | None = None) -> Tuple[str, ...]:
    """Backends able to execute ``spec`` on ``device`` (default: current)."""
    out = list(JNP_BACKENDS)
    if backend_universe(device) == "jnp+pallas":
        out.extend(PALLAS_BACKENDS)
    return tuple(out)


def build(spec: StencilSpec, backend: str, L: int) -> Callable:
    """Whole-stencil applicator for the 'pallas_direct' backend."""
    if backend != "pallas_direct":
        raise ValueError(f"dispatch.build handles pallas_direct, got {backend}")
    from repro.kernels.stencil_direct.ops import stencil1d, stencil2d

    w = np.asarray(spec.weights)
    r = spec.radius

    if spec.ndim == 1:
        return lambda x: stencil1d(w, x)

    if spec.ndim == 2:
        return lambda x: stencil2d(w, x)

    # 3-D: decompose the leading axis (paper §3.2.1 row decomposition,
    # lifted one dimension): y[a] = sum_u  stencil2d(w[u]) applied to x[a+u].
    def fn3d(x):
        n1 = x.shape[0] - 2 * r
        acc = None
        for u in range(2 * r + 1):
            if not np.any(w[u] != 0):
                continue
            part = jax.vmap(lambda s, wu=w[u]: stencil2d(wu, s))(x[u:u + n1])
            acc = part if acc is None else acc + part
        if acc is None:       # all-zero kernel: every slab skipped
            out_shape = (n1,) + tuple(s - 2 * r for s in x.shape[1:])
            return jnp.zeros(out_shape, dtype=x.dtype)
        return acc
    return fn3d
