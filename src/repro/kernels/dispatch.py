"""Engine backend -> Pallas kernel builders."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import StencilSpec


def build(spec: StencilSpec, backend: str, L: int) -> Callable:
    """Whole-stencil applicator for the 'pallas_direct' backend."""
    if backend != "pallas_direct":
        raise ValueError(f"dispatch.build handles pallas_direct, got {backend}")
    from repro.kernels.stencil_direct.ops import stencil1d, stencil2d

    w = np.asarray(spec.weights)
    r = spec.radius

    if spec.ndim == 1:
        return lambda x: stencil1d(w, x)

    if spec.ndim == 2:
        return lambda x: stencil2d(w, x)

    # 3-D: decompose the leading axis (paper §3.2.1 row decomposition,
    # lifted one dimension): y[a] = sum_u  stencil2d(w[u]) applied to x[a+u].
    def fn3d(x):
        n1 = x.shape[0] - 2 * r
        acc = None
        for u in range(2 * r + 1):
            if not np.any(w[u] != 0):
                continue
            part = jax.vmap(lambda s, wu=w[u]: stencil2d(wu, s))(x[u:u + n1])
            acc = part if acc is None else acc + part
        return acc
    return fn3d
