from repro.kernels.sptc_spmm.ops import sptc_spmm, sptc_spmm_windows
from repro.kernels.sptc_spmm.ref import sptc_spmm_ref, sptc_spmm_windows_ref

__all__ = ["sptc_spmm", "sptc_spmm_windows", "sptc_spmm_ref",
           "sptc_spmm_windows_ref"]
