"""Pallas TPU kernels: 2:4-compressed SpMM (simulated Sparse Tensor Core).

Two entry points:

``sptc_spmm_call`` — the v1 building block: a plain compressed SpMM over a
pre-swapped RHS.  Faithful executable semantics of ``mma.sp``: per output
row i and 4-wide reduction segment s, only the two RHS rows selected by
the 2-bit metadata contribute.  TPU has no SpTC, so the kernel realizes
the selection as an in-VMEM decompression (VPU one-hot expansion over the
tiny K dim) followed by a dense MXU matmul over the N (free) dimension.

``sptc_fused_call`` — the v2 fused stencil executor (paper §3.3 "zero
runtime overhead"): ONE Pallas program that, per (N-tile, row-tile) grid
step,

  1. DMAs the overlapping (2L, bn) input window straight from HBM into
     VMEM scratch, double-buffered across sequential grid steps (the
     t+1 window prefetches while tile t computes);
  2. folds the strided row swap AND the 2-bit metadata gather into the
     decompression's comparison positions — the swap permutation is the
     closed form ``p odd: p <-> p±L`` so it is derived from an iota
     inside the kernel, and the metadata is unpacked in-register from
     the packed ``meta_bits`` words.  Nothing is permuted or gathered
     outside the kernel;
  3. runs the dense MXU matmul (f32, or bf16 inputs with f32
     accumulation via ``compute_dtype="bfloat16"``).

Star fast path (``star_fast=True``): when the composed swap∘meta gather
is the identity band of the taps (see ``core.sparsify
.contiguous_band_values``), the metadata carries no information — the
kernel skips the one-hot decompression and performs K/2 shifted VPU FMAs
over the banded value layout, touching no metadata at all.

Blocking: the compressed operand (M = L, K/2) and metadata words are tiny
and live whole in VMEM; the input stays in HBM (``pl.ANY``) because the
overlapping 2L-row windows cannot be expressed as disjoint BlockSpec
tiles; outputs are tiled (L, bn) with N in 128-lane multiples.

Both ``*_call`` entry points resolve ``interpret=None`` through
``common.default_interpret()`` at call time: compiled Mosaic on a real
TPU, interpret mode elsewhere, overridable via ``REPRO_PALLAS_INTERPRET``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import round_up


def _sptc_kernel(values_ref, meta_ref, x_ref, y_ref, *, k: int):
    vals = values_ref[:]                       # (M, K/2)
    meta = meta_ref[:]                         # (M, K/2) int32
    x = x_ref[:]                               # (K, bn)
    m, kh = vals.shape
    # gather index per compressed slot: 4*segment + 2-bit position
    seg = (jax.lax.broadcasted_iota(jnp.int32, (m, kh), 1) // 2) * 4
    gidx = seg + meta                          # (M, K/2)
    # In-VMEM decompression: scatter values to their K positions via one-hot.
    # K is tiny (= 2L); this is VPU work, the MXU then runs the dense dot.
    kpos = jax.lax.broadcasted_iota(jnp.int32, (m, kh, k), 2)
    onehot = (gidx[:, :, None] == kpos).astype(vals.dtype)
    w = jnp.sum(vals[:, :, None] * onehot, axis=1)          # (M, K)
    y_ref[:] = jnp.dot(w, x, preferred_element_type=jnp.float32
                       ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _sptc_spmm_jit(values, meta, x, *, block_n: int, interpret: bool):
    m, kh = values.shape
    k, n = x.shape
    if kh * 2 != k:
        raise ValueError(f"K/2 mismatch: values {kh} vs x K={k}")
    bn = min(block_n, round_up(n, 128))
    n_pad = round_up(n, bn)
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    grid = (n_pad // bn,)
    y = pl.pallas_call(
        functools.partial(_sptc_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, kh), lambda i: (0, 0)),     # compressed values
            pl.BlockSpec((m, kh), lambda i: (0, 0)),     # metadata
            pl.BlockSpec((k, bn), lambda i: (0, i)),     # RHS N-tile
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n_pad), x.dtype),
        interpret=interpret,
    )(values.astype(x.dtype), meta.astype(jnp.int32), x)
    return y[:, :n]


def sptc_spmm_call(values, meta, x, *, block_n: int = 512,
                   interpret: bool | None = None):
    """y = SpTC(values, meta) @ x.   values/meta: (M, K/2); x: (K, N)."""
    if interpret is None:
        interpret = common.default_interpret()
    return _sptc_spmm_jit(values, meta, x, block_n=block_n,
                          interpret=interpret)


# ---------------------------------------------------------------------------
# v2: fused window-DMA + in-kernel swap/gather + MXU matmul
# ---------------------------------------------------------------------------

def _fused_kernel(x_hbm, vals_ref, meta_ref, y_ref, scratch, sem, *,
                  tiles: int, L: int, bn: int, star_fast: bool, compute):
    t = pl.program_id(1)
    j = pl.program_id(0)
    kh = vals_ref.shape[1]

    def dma(slot, tt):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(tt * L, 2 * L), pl.ds(j * bn, bn)],
            scratch.at[slot], sem.at[slot])

    # cross-grid-step double buffering: scratch persists across the
    # sequential row-tile axis (grid iterates it fastest), so tile t+1's
    # window streams from HBM while tile t computes.
    @pl.when(t == 0)
    def _():
        dma(0, 0).start()

    @pl.when(t + 1 < tiles)
    def _():
        dma((t + 1) % 2, t + 1).start()

    dma(t % 2, t).wait()
    win = scratch[t % 2]                     # (2L, bn)
    vals = vals_ref[:]                       # (M, K/2)
    if compute is not None:
        win = win.astype(compute)
        vals = vals.astype(compute)
    if star_fast:
        # banded value layout: row m's slot off reads window row m + off —
        # no metadata, K/2 shifted VPU FMAs with f32 accumulation.
        acc = jnp.zeros((L, bn), dtype=jnp.float32)
        for jj in range(kh):
            acc = acc + vals[:, jj:jj + 1].astype(jnp.float32) * \
                win[jj:jj + L, :].astype(jnp.float32)
        y_ref[:] = acc.astype(y_ref.dtype)
    else:
        # unpack the 2-bit metadata from the packed words in-register
        words = meta_ref[:]                  # (M, nwords) uint32
        m = words.shape[0]
        nwords = words.shape[1]
        exp = jnp.concatenate(
            [jnp.broadcast_to(words[:, w:w + 1], (m, 16))
             for w in range(nwords)], axis=1)[:, :kh]
        jj = jax.lax.broadcasted_iota(jnp.int32, (m, kh), 1)
        shifts = (2 * (jj % 16)).astype(jnp.uint32)
        meta = (jax.lax.shift_right_logical(exp, shifts) & 3
                ).astype(jnp.int32)
        gidx = 4 * (jj // 2) + meta                            # (M, K/2)
        # strided swap folded into the decompression positions: position p
        # of the window holds source row perm[p], and the permutation has
        # the closed form "odd p exchanges halves" — derived from an iota,
        # so the swap costs zero loads and zero stores (§3.3).
        p = jax.lax.broadcasted_iota(jnp.int32, (m, kh, 2 * L), 2)
        kpos = jnp.where(p % 2 == 1, jnp.where(p < L, p + L, p - L), p)
        onehot = (gidx[:, :, None] == kpos)
        w_dense = jnp.sum(vals[:, :, None] * onehot.astype(vals.dtype),
                          axis=1)                              # (M, 2L)
        y_ref[:] = jnp.dot(w_dense, win, preferred_element_type=jnp.float32
                           ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "n_out", "L", "block_n", "star_fast", "compute_dtype", "interpret"))
def _sptc_fused_jit(values, meta_bits, x2d, *, n_out: int, L: int,
                    block_n: int, star_fast: bool, compute_dtype,
                    interpret: bool):
    rows, c = x2d.shape
    m, kh = values.shape
    tiles = -(-n_out // L)
    need = (tiles + 1) * L
    if need > rows:
        x2d = jnp.pad(x2d, ((0, need - rows), (0, 0)))
    bn = min(block_n, round_up(c, 128))
    c_pad = round_up(c, bn)
    if c_pad != c:
        x2d = jnp.pad(x2d, ((0, 0), (0, c_pad - c)))
    compute = jnp.dtype(compute_dtype) if compute_dtype else None
    kern = functools.partial(_fused_kernel, tiles=tiles, L=L, bn=bn,
                             star_fast=star_fast, compute=compute)
    y = pl.pallas_call(
        kern,
        grid=(c_pad // bn, tiles),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),             # input in HBM
            pl.BlockSpec((m, kh), lambda j, t: (0, 0)),
            pl.BlockSpec(meta_bits.shape, lambda j, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((L, bn), lambda j, t: (t, j)),
        out_shape=jax.ShapeDtypeStruct((tiles * L, c_pad), x2d.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, 2 * L, bn), x2d.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(x2d, values, meta_bits)
    return y[:n_out, :c]


def sptc_fused_call(values, meta_bits, x2d, *, n_out: int, L: int,
                    block_n: int = 512, star_fast: bool = False,
                    compute_dtype: str | None = None,
                    interpret: bool | None = None):
    """Fused stencil SpMM: y[i] = sum_j band(i, j) * x2d[i + ...].

    ``values``    (L, K/2) compressed operand — the banded layout from
                  ``contiguous_band_values`` when ``star_fast=True``.
    ``meta_bits`` (L, ceil(K/32)) packed uint32 metadata words.
    ``x2d``       (>= n_out + L, C) input rows, UNswapped — the swap
                  happens inside the kernel.
    Returns the (n_out, C) stencil output.
    """
    if interpret is None:
        interpret = common.default_interpret()
    return _sptc_fused_jit(values, meta_bits, x2d, n_out=n_out, L=L,
                           block_n=block_n, star_fast=star_fast,
                           compute_dtype=compute_dtype, interpret=interpret)
