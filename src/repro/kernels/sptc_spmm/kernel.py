"""Pallas TPU kernel: 2:4-compressed SpMM (simulated Sparse Tensor Core).

Faithful executable semantics of ``mma.sp``: per output row i and 4-wide
reduction segment s, only the two RHS rows selected by the 2-bit metadata
contribute. TPU has no SpTC, so the kernel realizes the selection as an
in-VMEM decompression (VPU one-hot expansion over the tiny K dim — the
metadata is typically static stencil structure) followed by a dense MXU
matmul over the N (free) dimension, which is where the FLOPs are.

Blocking: the compressed operand (M, K/2) and metadata are tiny (M = L =
2r+2, K = 2L) and live whole in VMEM; the RHS/output are tiled over N in
128-lane multiples — BlockSpec (K, bn) / (M, bn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import round_up


def _sptc_kernel(values_ref, meta_ref, x_ref, y_ref, *, k: int):
    vals = values_ref[:]                       # (M, K/2)
    meta = meta_ref[:]                         # (M, K/2) int32
    x = x_ref[:]                               # (K, bn)
    m, kh = vals.shape
    # gather index per compressed slot: 4*segment + 2-bit position
    seg = (jax.lax.broadcasted_iota(jnp.int32, (m, kh), 1) // 2) * 4
    gidx = seg + meta                          # (M, K/2)
    # In-VMEM decompression: scatter values to their K positions via one-hot.
    # K is tiny (= 2L); this is VPU work, the MXU then runs the dense dot.
    kpos = jax.lax.broadcasted_iota(jnp.int32, (m, kh, k), 2)
    onehot = (gidx[:, :, None] == kpos).astype(vals.dtype)
    w = jnp.sum(vals[:, :, None] * onehot, axis=1)          # (M, K)
    y_ref[:] = jnp.dot(w, x, preferred_element_type=jnp.float32
                       ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sptc_spmm_call(values, meta, x, *, block_n: int = 512,
                   interpret: bool = True):
    """y = SpTC(values, meta) @ x.   values/meta: (M, K/2); x: (K, N)."""
    m, kh = values.shape
    k, n = x.shape
    if kh * 2 != k:
        raise ValueError(f"K/2 mismatch: values {kh} vs x K={k}")
    bn = min(block_n, round_up(n, 128))
    n_pad = round_up(n, bn)
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    grid = (n_pad // bn,)
    y = pl.pallas_call(
        functools.partial(_sptc_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, kh), lambda i: (0, 0)),     # compressed values
            pl.BlockSpec((m, kh), lambda i: (0, 0)),     # metadata
            pl.BlockSpec((k, bn), lambda i: (0, i)),     # RHS N-tile
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n_pad), x.dtype),
        interpret=interpret,
    )(values.astype(x.dtype), meta.astype(jnp.int32), x)
    return y[:, :n]
