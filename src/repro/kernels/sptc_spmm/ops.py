"""Jitted public wrappers for the 2:4 compressed SpMM kernels."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsify import (Sparse24, contiguous_band_values,
                                 strided_swap_perm)
from repro.kernels.sptc_spmm.kernel import sptc_fused_call, sptc_spmm_call


def sptc_spmm(values, meta, x, *, block_n: int = 512,
              interpret: bool | None = None):
    """Compressed (M, K/2) x (K, N) -> (M, N)."""
    return sptc_spmm_call(jnp.asarray(values), jnp.asarray(meta),
                          jnp.asarray(x), block_n=block_n,
                          interpret=interpret)


def sptc_spmm_windows(values, meta, windows, *, block_n: int = 512,
                      interpret: bool | None = None):
    """Batched over the leading tile axis: (T, K, N) -> (T, M, N).

    vmap adds the tile axis as an outer grid dimension of the pallas_call.
    """
    values = jnp.asarray(values)
    meta = jnp.asarray(meta)
    fn = lambda w: sptc_spmm_call(values, meta, w, block_n=block_n,
                                  interpret=interpret)
    return jax.vmap(fn)(jnp.asarray(windows))


def sptc_spmm_fused(operand: Sparse24, perm, x2d, *, n_out: int, L: int,
                    star_fast: "bool | str" = "auto", block_n: int = 512,
                    compute_dtype: Optional[str] = None,
                    interpret: bool | None = None):
    """One fused Pallas program: window DMA → in-kernel swap+gather → MXU.

    ``x2d`` is the raw (n_out + 2r, C) haloed input — NOT windowed, NOT
    swapped; the kernel folds both into its load addressing (§3.3).  All
    tables (compressed values, packed meta words, the fast-path banded
    layout) are computed here in NumPy at trace time, so under ``jax.jit``
    they are compile-time constants: slight compile time, zero runtime.

    ``star_fast``: ``"auto"`` uses the metadata-free banded path whenever
    the swap∘meta gather is the identity band of the taps; ``True``
    requires it (ValueError if the operand's pattern escapes the band);
    ``False`` always runs the faithful one-hot decompression.
    """
    perm = np.asarray(perm)
    if not np.array_equal(perm, strided_swap_perm(L)):
        raise ValueError(
            "sptc_spmm_fused requires the strided-swap permutation — the "
            "kernel derives it in closed form from an iota (§3.3)")
    fast_vals = (contiguous_band_values(operand, perm)
                 if star_fast in ("auto", True) else None)
    if star_fast is True and fast_vals is None:
        raise ValueError("operand's 2:4 pattern is not the identity band "
                         "of the taps; star fast path unavailable")
    x2d = jnp.asarray(x2d)
    meta_bits = jnp.asarray(operand.meta_bits())
    vals = np.asarray(fast_vals if fast_vals is not None
                      else operand.values)
    return sptc_fused_call(
        jnp.asarray(vals, dtype=x2d.dtype), meta_bits, x2d,
        n_out=n_out, L=L, block_n=block_n,
        star_fast=fast_vals is not None,
        compute_dtype=compute_dtype, interpret=interpret)
