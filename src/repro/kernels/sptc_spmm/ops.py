"""Jitted public wrappers for the 2:4 compressed SpMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.sptc_spmm.kernel import sptc_spmm_call


def sptc_spmm(values, meta, x, *, block_n: int = 512,
              interpret: bool | None = None):
    """Compressed (M, K/2) x (K, N) -> (M, N)."""
    if interpret is None:
        interpret = common.default_interpret()
    return sptc_spmm_call(jnp.asarray(values), jnp.asarray(meta),
                          jnp.asarray(x), block_n=block_n,
                          interpret=interpret)


def sptc_spmm_windows(values, meta, windows, *, block_n: int = 512,
                      interpret: bool | None = None):
    """Batched over the leading tile axis: (T, K, N) -> (T, M, N).

    vmap adds the tile axis as an outer grid dimension of the pallas_call.
    """
    if interpret is None:
        interpret = common.default_interpret()
    values = jnp.asarray(values)
    meta = jnp.asarray(meta)
    fn = lambda w: sptc_spmm_call(values, meta, w, block_n=block_n,
                                  interpret=interpret)
    return jax.vmap(fn)(jnp.asarray(windows))
