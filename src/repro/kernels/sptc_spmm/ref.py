"""Pure-jnp oracle for the 2:4 compressed SpMM (simulated SpTC semantics)."""
from __future__ import annotations

from repro.core.sptc import sptc_matmul


def sptc_spmm_ref(values, meta, x):
    """(M, K/2) values + metadata  x  (K, N)  ->  (M, N)."""
    return sptc_matmul(values, meta, x)


def sptc_spmm_windows_ref(values, meta, windows):
    """Batched over leading tile axis: windows (T, K, N) -> (T, M, N)."""
    import jax
    return jax.vmap(lambda w: sptc_matmul(values, meta, w))(windows)
