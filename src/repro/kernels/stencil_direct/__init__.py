from repro.kernels.stencil_direct.ops import stencil1d, stencil2d
from repro.kernels.stencil_direct.ref import stencil2d_ref

__all__ = ["stencil1d", "stencil2d", "stencil2d_ref"]
