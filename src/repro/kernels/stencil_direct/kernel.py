"""Pallas TPU kernel: tiled direct (VPU) 2-D stencil with DMA halo loads.

This is the TPU bandwidth-roofline kernel. The input stays in HBM
(``pl.ANY``); each grid step DMAs one (th + 2rh, W + 2rw) halo row-block
into a VMEM scratch buffer — the overlapping halo rows are re-read from HBM
exactly as a GPU kernel re-reads them into shared memory — then the output
tile is accumulated with statically-unrolled shifted FMAs (one VPU
multiply-add per non-zero tap; star stencils skip their zero taps at trace
time). The stencil weights are compile-time constants, matching the paper's
observation that the kernel matrix is static structure, not data.

Roofline: for an H x W fp32 grid the kernel moves ~4(H W) bytes in + 4(H W)
out (+ halo), and performs taps x H x W FMAs — memory-bound for r <= 2,
VPU-compute-bound for box r >= 3 (analysis in core/analysis.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _stencil_kernel(x_hbm, y_ref, scratch, sem, *, taps, th, w_out, rh, rw):
    i = pl.program_id(0)
    rows = th + 2 * rh
    cp = pltpu.make_async_copy(
        x_hbm.at[pl.ds(i * th, rows), :], scratch, sem)
    cp.start()
    cp.wait()
    acc = jnp.zeros((th, w_out), dtype=jnp.float32)
    for (u, v, wt) in taps:                     # statically unrolled VPU FMAs
        acc = acc + wt * scratch[u:u + th, v:v + w_out].astype(jnp.float32)
    y_ref[:] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("taps", "rh", "rw", "th", "interpret"))
def _stencil2d_jit(x, *, taps, rh: int, rw: int, th: int, interpret: bool):
    h_in, w_in = x.shape
    h_out = h_in - 2 * rh
    w_out = w_in - 2 * rw
    grid_h = -(-h_out // th)
    # pad rows so the final tile's halo DMA stays in bounds
    h_need = grid_h * th + 2 * rh
    if h_need > h_in:
        x = jnp.pad(x, ((0, h_need - h_in), (0, 0)))
    y = pl.pallas_call(
        functools.partial(_stencil_kernel, taps=taps, th=th,
                          w_out=w_out, rh=rh, rw=rw),
        grid=(grid_h,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((th, w_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid_h * th, w_out), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((th + 2 * rh, w_in), x.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(x)
    return y[:h_out]


def stencil2d_call(x, *, taps, rh: int, rw: int, th: int = 128,
                   interpret: bool | None = None):
    """Apply a 2-D stencil. x: (H + 2rh, W + 2rw) -> (H, W).

    ``taps`` is a static tuple of (u, v, weight) non-zero stencil entries.
    Caller is responsible for lane padding of W (ops.py handles it).
    """
    if interpret is None:
        interpret = common.default_interpret()
    return _stencil2d_jit(x, taps=taps, rh=rh, rw=rw, th=th,
                          interpret=interpret)
