"""Jitted wrappers: direct Pallas stencil for 1-D and 2-D problems."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import common
from repro.kernels.stencil_direct.kernel import stencil2d_call


def _taps(weights: np.ndarray):
    """Static (u, v, weight) tuple of non-zero taps (star taps pruned)."""
    kh, kw = weights.shape
    return tuple((u, v, float(weights[u, v]))
                 for u in range(kh) for v in range(kw)
                 if weights[u, v] != 0)


def stencil2d(weights: np.ndarray, x, *, th: int = 128,
              interpret: bool | None = None):
    """weights (2rh+1, 2rw+1); x (H+2rh, W+2rw) -> (H, W)."""
    if interpret is None:
        interpret = common.default_interpret()
    weights = np.asarray(weights)
    kh, kw = weights.shape
    rh, rw = (kh - 1) // 2, (kw - 1) // 2
    h_in, w_in = x.shape
    w_out = w_in - 2 * rw
    # lane padding: output width to 128 multiple (zero-pad input columns)
    w_out_p = common.round_up(max(w_out, 1), common.LANES)
    if w_out_p != w_out:
        x = jnp.pad(x, ((0, 0), (0, w_out_p - w_out)))
    th = min(th, common.round_up(h_in - 2 * rh, common.SUBLANES))
    y = stencil2d_call(x, taps=_taps(weights), rh=rh, rw=rw, th=th,
                       interpret=interpret)
    return y[:, :w_out]


def stencil1d(weights: np.ndarray, x, *, interpret: bool | None = None):
    """1-D stencil as a 2-D problem with rh = 0.

    x: (N + 2r,) -> (N,). The row dim is tiled to expose parallelism: the
    flat vector is viewed as (rows, W) with per-row halo columns overlapping.
    """
    weights = np.asarray(weights).reshape(1, -1)
    y = stencil2d(weights, jnp.asarray(x)[None, :], interpret=interpret)
    return y[0]
