"""Pure-jnp oracle: direct shifted multiply-add stencil (1-D/2-D)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def stencil2d_ref(weights: np.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """weights (2rh+1, 2rw+1); x (H+2rh, W+2rw) -> (H, W)."""
    kh, kw = weights.shape
    h = x.shape[0] - (kh - 1)
    w = x.shape[1] - (kw - 1)
    acc = jnp.zeros((h, w), dtype=x.dtype)
    for u in range(kh):
        for v in range(kw):
            if weights[u, v] != 0:
                acc = acc + jnp.asarray(weights[u, v], x.dtype) * x[u:u + h, v:v + w]
    return acc
