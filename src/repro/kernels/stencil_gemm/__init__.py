from repro.kernels.stencil_gemm.ops import windows_gemm
from repro.kernels.stencil_gemm.ref import windows_gemm_ref

__all__ = ["windows_gemm", "windows_gemm_ref"]
