"""Pallas TPU kernel: dense kernel-matrix GEMM over input windows.

The paper-faithful §3.2.1 executor (generalized TCStencil): the banded
(L, 2L) kernel matrix multiplies 2L-row input windows, updating L outputs
per window column. This is the *dense* Tensor-Core analogue — it performs
the full 2x-redundant MAC count that SpTC (and our compressed kernel)
eliminates; it exists as the measured baseline for that comparison.

Blocking: kernel matrix whole in VMEM (tiny); windows tiled (1, 2L, bn);
MXU does the (L, 2L) x (2L, bn) dot per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common
from repro.kernels.common import round_up


def _gemm_kernel(km_ref, win_ref, y_ref):
    km = km_ref[:]                    # (L, K)
    win = win_ref[0]                  # (K, bn)
    y_ref[0] = jnp.dot(km, win, preferred_element_type=jnp.float32
                       ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _windows_gemm_jit(km, windows, *, block_n: int, interpret: bool):
    l, k = km.shape
    t, k2, c = windows.shape
    if k2 != k:
        raise ValueError(f"K mismatch {k2} vs {k}")
    bn = min(block_n, round_up(c, 128))
    c_pad = round_up(c, bn)
    if c_pad != c:
        windows = jnp.pad(windows, ((0, 0), (0, 0), (0, c_pad - c)))
    y = pl.pallas_call(
        _gemm_kernel,
        grid=(t, c_pad // bn),
        in_specs=[
            pl.BlockSpec((l, k), lambda i, j: (0, 0)),
            pl.BlockSpec((1, k, bn), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, l, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((t, l, c_pad), windows.dtype),
        interpret=interpret,
    )(km.astype(windows.dtype), windows)
    return y[:, :, :c]


def windows_gemm_call(km, windows, *, block_n: int = 512,
                      interpret: bool | None = None):
    """km (L, K); windows (T, K, C) -> (T, L, C)."""
    if interpret is None:
        interpret = common.default_interpret()
    return _windows_gemm_jit(km, windows, block_n=block_n,
                             interpret=interpret)
