"""Jitted wrapper for the dense windows-GEMM stencil executor."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.stencil_gemm.kernel import windows_gemm_call


def windows_gemm(km, windows, *, block_n: int = 512,
                 interpret: bool | None = None):
    if interpret is None:
        interpret = common.default_interpret()
    return windows_gemm_call(jnp.asarray(km), jnp.asarray(windows),
                             block_n=block_n, interpret=interpret)
