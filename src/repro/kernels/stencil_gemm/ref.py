"""Pure-jnp oracle for the windows x kernel-matrix GEMM stencil."""
from __future__ import annotations

import jax.numpy as jnp


def windows_gemm_ref(km, windows):
    """km (L, K); windows (T, K, C) -> (T, L, C)."""
    return jnp.einsum("lk,tkc->tlc", km, windows,
                      preferred_element_type=jnp.float32
                      ).astype(windows.dtype)
