"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE VERY FIRST TWO LINES (before any other import, including repro.*) force
512 placeholder host devices so jax.make_mesh can build the production
meshes — jax locks the device count on first init. This flag is set ONLY
here: smoke tests and benches see the single real CPU device.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse                                                  # noqa: E402
import json                                                      # noqa: E402
import time                                                      # noqa: E402
import traceback                                                 # noqa: E402
from typing import Any, Dict, Optional                           # noqa: E402

import jax                                                       # noqa: E402
import jax.numpy as jnp                                          # noqa: E402
import numpy as np                                               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P      # noqa: E402

from repro.configs.base import ShapeCell                         # noqa: E402
from repro.configs.registry import (ARCHS, get_config,           # noqa: E402
                                    input_specs, iter_cells)
from repro.distributed.sharding import (default_rules,           # noqa: E402
                                        param_shardings, spec_for,
                                        use_mesh_rules)
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.models import model as M                              # noqa: E402
from repro.models.nn import axes_tree                            # noqa: E402
from repro.roofline.analysis import (from_compiled,              # noqa: E402
                                     model_flops_for_cell)
from repro.serving import engine as E                            # noqa: E402
from repro.training import optimizer as O                        # noqa: E402
from repro.training.train_step import (TrainConfig, TrainState,  # noqa: E402
                                       train_step)


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _params_shapes_and_axes(cfg, key_spec):
    axes_store: Dict[str, Any] = {}

    def init_fn(key):
        params, axes = M.init_params(cfg, key)
        axes_store.update(axes)
        return params

    shapes = jax.eval_shape(init_fn, key_spec)
    return shapes, axes_tree(shapes, axes_store)


def _state_shardings(cfg, mesh, rules, p_shapes, p_axes):
    psh = param_shardings(p_axes, p_shapes, rules, mesh)
    rep = NamedSharding(mesh, P())
    return TrainState(
        params=psh,
        opt=O.OptState(step=rep, mu=psh, nu=psh, master=psh))


def _batch_axes(multi_pod):
    return ("pod", "data") if multi_pod else ("data",)


def _batch_part(mesh, multi_pod, batch: int):
    """Batch-dim partition with divisibility fallback (long_500k has B=1)."""
    axes = [a for a in _batch_axes(multi_pod) if a in mesh.shape]
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if total <= 1 or batch % total != 0:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def _cache_shardings(cfg, cache_shapes, mesh, rules):
    """NamedShardings for a decode cache pytree by leaf role."""
    batch = rules.acts["batch"]

    def leaf_spec(path, leaf):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if leaf.ndim == 0 or "pos" in name:
            return P()
        if name.endswith("k") or name.endswith("v"):
            # (L, B, ring, Kh, Dh) — Dh absorbs 'model' when Kh can't
            ax = (None, "batch", None, "kv_heads", "head")
        elif "ssm" in name:
            ax = (None, "batch", "heads_model", None, None)
        elif "conv" in name:
            ax = (None, "batch", None, "mlp")
        else:
            ax = (None,) * leaf.ndim
        rule = dict(rules.acts)
        rule["kv_heads"] = "model"
        rule["heads_model"] = "model"
        rule["head"] = None          # spec_for fallback may claim 'model'
        rule["mlp"] = "model"
        rule["batch"] = batch
        return spec_for(ax, leaf.shape, rule, mesh, head_fallback=True)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, leaf_spec(path, leaf)),
        cache_shapes)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, cell: ShapeCell, *, multi_pod: bool,
               rules=None, extra_tag: str = "",
               cfg_override=None, tc: Optional[TrainConfig] = None,
               mesh_override=None) -> Dict[str, Any]:
    """Lower + compile one cell; return dry-run record (or raise).

    mesh_override: (shape_tuple, axes_tuple) — §Perf hillclimb alternative
    meshes (e.g. ((64, 4), ("data", "model"))), chips must still total
    256/512 so comparisons stay per-fleet.
    """
    cfg = cfg_override or get_config(arch)
    if mesh_override is not None:
        shape, axes = mesh_override
        mesh = jax.make_mesh(shape, axes)
        mesh_name = "x".join(map(str, shape)) + extra_tag
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = ("2x16x16" if multi_pod else "16x16") + extra_tag
    chips = int(np.prod(list(mesh.shape.values())))
    if rules is None:
        rules = default_rules(multi_pod=multi_pod)
    batch_ax = _batch_part(mesh, multi_pod, cell.global_batch)

    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    p_shapes, p_axes = _params_shapes_and_axes(cfg, key_spec)
    specs = input_specs(cfg, cell)
    t0 = time.monotonic()

    with mesh:
        with use_mesh_rules(mesh, rules):
            if cell.kind == "train":
                # microbatches=4: grad-accum bounds live activations so the
                # 4k x 256 train cells fit 16 GB HBM (see EXPERIMENTS.md)
                tc = tc or TrainConfig(microbatches=4)
                st_shapes = TrainState(
                    params=p_shapes,
                    opt=jax.eval_shape(O.init, p_shapes))
                st_sh = _state_shardings(cfg, mesh, rules, p_shapes, p_axes)
                tok_sh = NamedSharding(mesh, P(batch_ax, None))
                in_sh = [st_sh, tok_sh]
                args = [st_shapes, specs["tokens"]]
                if "memory" in specs:
                    in_sh.append(NamedSharding(mesh, P(batch_ax, None, None)))
                    args.append(specs["memory"])

                def step(state, tokens, memory=None):
                    return train_step(cfg, tc, state, tokens, memory)

                jitted = jax.jit(step, in_shardings=tuple(in_sh),
                                 donate_argnums=(0,))
                lowered = jitted.lower(*args)

            elif cell.kind == "prefill":
                psh = param_shardings(p_axes, p_shapes, rules, mesh)
                tok_sh = NamedSharding(mesh, P(batch_ax, None))
                in_sh = [psh, tok_sh]
                args = [p_shapes, specs["tokens"]]
                if "memory" in specs:
                    in_sh.append(NamedSharding(mesh, P(batch_ax, None, None)))
                    args.append(specs["memory"])

                def step(params, tokens, memory=None):
                    return E.prefill(params, cfg, tokens, cell.seq_len,
                                     memory=memory)

                jitted = jax.jit(step, in_shardings=tuple(in_sh))
                lowered = jitted.lower(*args)

            else:  # decode
                psh = param_shardings(p_axes, p_shapes, rules, mesh,
                                      head_fallback=True)
                cache_sh = _cache_shardings(cfg, specs["cache"], mesh, rules)
                tok_sh = NamedSharding(mesh, P(batch_ax, None))

                def step(params, cache, token):
                    return E.decode_step(params, cfg, cache, token)

                jitted = jax.jit(
                    step, in_shardings=(psh, cache_sh, tok_sh),
                    donate_argnums=(1,))
                lowered = jitted.lower(p_shapes, specs["cache"],
                                       specs["token"])

            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower

    mf = model_flops_for_cell(cfg, cell, p_shapes)
    rl = from_compiled(compiled, arch=arch, cell=cell.name,
                       mesh_name=mesh_name, chips=chips, model_flops=mf)
    mem = compiled.memory_analysis()
    rec = rl.row()
    rec.update({
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "arg_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "out_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
    })
    return rec


def run_sweep(archs, cells, multi_pod: bool, out_path: Optional[str],
              resume: bool = True) -> Dict:
    """Sweep cells; append-write JSONL so an interrupted sweep resumes."""
    done = set()
    if out_path and resume and os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):          # failed cells retry on resume
                    done.add((r["arch"], r["cell"], r["mesh"]))
    results = []
    mesh_name = "2x16x16" if multi_pod else "16x16"
    for arch in archs:
        for cell, skip in iter_cells(arch):
            if cells and cell.name not in cells:
                continue
            key = (arch, cell.name, mesh_name)
            if key in done:
                continue
            if skip:
                rec = {"arch": arch, "cell": cell.name, "mesh": mesh_name,
                       "ok": True, "skipped": skip}
            else:
                print(f"--- {arch} x {cell.name} x {mesh_name}", flush=True)
                try:
                    rec = lower_cell(arch, cell, multi_pod=multi_pod)
                    print(f"    ok: compile {rec['compile_s']}s "
                          f"bottleneck={rec['bottleneck']} "
                          f"perdev={rec['per_device_gb']:.2f}GB", flush=True)
                except Exception as e:                     # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "cell": cell.name,
                           "mesh": mesh_name, "ok": False, "error": str(e)}
            results.append(rec)
            if out_path:
                with open(out_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    return {"results": results}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--cell", default=None,
                    help="one of train_4k/prefill_32k/decode_32k/long_500k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCHS)
    cells = [args.cell] if args.cell else None
    out = run_sweep(archs, cells, args.multi_pod, args.out,
                    resume=not args.no_resume)
    n_ok = sum(1 for r in out["results"] if r.get("ok"))
    print(f"\n{n_ok}/{len(out['results'])} cells OK")
    if any(not r.get("ok") for r in out["results"]):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
