"""Production mesh builders.

FUNCTIONS, not module constants — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).

Mesh topology (TPU v5e pods):
  single-pod  (16, 16)        axes (data, model)   = 256 chips
  multi-pod   (2, 16, 16)     axes (pod, data, model) = 512 chips
The 'pod' axis composes with 'data' for gradient reduction (hierarchical:
reduce-scatter over ICI within a pod, all-reduce across pods over DCN).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this process actually has — smoke/bench mesh."""
    n = jax.device_count()
    return jax.make_mesh((n,), ("data",))
