"""§Perf hillclimb driver: lower one cell under a named variant and report
the three roofline terms — the measurement half of the hypothesis ->
change -> measure -> validate loop (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.perf --arch qwen3-1.7b \
        --cell train_4k --variant mesh64x4

Variants are combinations of mesh shape, sharding rules, remat policy and
microbatching — the knobs the hypothesis log iterates over.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse                                                  # noqa: E402
import json                                                      # noqa: E402
from typing import Any, Dict, Optional                           # noqa: E402

from repro.configs.registry import get_config                    # noqa: E402
from repro.distributed.sharding import sp_rules                  # noqa: E402
from repro.launch.dryrun import lower_cell                       # noqa: E402
from repro.training.train_step import TrainConfig                # noqa: E402


def variant_kwargs(name: str, arch: str) -> Dict[str, Any]:
    """Named experiment variants (single-pod, 256 chips unless noted)."""
    cfg = get_config(arch)
    v: Dict[str, Any] = {"multi_pod": False, "extra_tag": f"/{name}"}
    if "+" in name:                               # composition a+b (first!)
        out: Dict[str, Any] = {"multi_pod": False, "extra_tag": f"/{name}"}
        merged_cfg = cfg
        for part in name.split("+"):
            pv = variant_kwargs(part, arch)
            if "cfg_override" in pv:
                delta = {f: getattr(pv["cfg_override"], f)
                         for f in ("remat_policy", "sliding_window",
                                   "attn_block_kv", "remat",
                                   "banded_attention", "attn_block_q",
                                   "moe_dispatch_dtype", "moe_group")
                         if getattr(pv["cfg_override"], f) !=
                         getattr(cfg, f)}
                merged_cfg = merged_cfg.scaled(**delta)
                out["cfg_override"] = merged_cfg
            for k2 in ("mesh_override", "tc", "rules"):
                if k2 in pv:
                    out[k2] = pv[k2]
        return out
    if name == "baseline":
        pass
    elif name.startswith("mesh"):                 # mesh64x4, mesh2x32x8, ...
        dims = [int(x) for x in name[4:].split("x")]
        if len(dims) == 3:                        # multi-pod variant
            v["mesh_override"] = (tuple(dims), ("pod", "data", "model"))
            v["multi_pod"] = True
        else:
            v["mesh_override"] = (tuple(dims), ("data", "model"))
    elif name == "remat_dots":
        v["cfg_override"] = cfg.scaled(remat_policy="dots")
    elif name == "remat_none":
        v["cfg_override"] = cfg.scaled(remat=False)
    elif name.startswith("mb") and name.endswith("gc"):   # mb1gc: mb + bf16
        v["tc"] = TrainConfig(microbatches=int(name[2:-2]),
                              grad_compress=True)
    elif name.startswith("mb"):                   # mb1, mb8, mb16
        v["tc"] = TrainConfig(microbatches=int(name[2:]))
    elif name == "grad_compress":
        v["tc"] = TrainConfig(microbatches=4, grad_compress=True)
    elif name == "seqpar":
        v["rules"] = sp_rules()
    elif name == "banded":                        # SWA band-skip attention
        v["cfg_override"] = cfg.scaled(banded_attention=True)
    elif name.startswith("bq"):                   # bq1024: banded q-chunk
        v["cfg_override"] = cfg.scaled(banded_attention=True,
                                       attn_block_q=int(name[2:]))
    elif name.startswith("swa"):                  # swa1024: shrink window
        v["cfg_override"] = cfg.scaled(sliding_window=int(name[3:]))
    elif name.startswith("blockkv"):              # blockkv4096
        v["cfg_override"] = cfg.scaled(attn_block_kv=int(name[7:]))
    elif name == "moebf16":                       # bf16 dispatch one-hots
        v["cfg_override"] = cfg.scaled(moe_dispatch_dtype="bfloat16")
    elif name.startswith("moegroup"):             # moegroup256
        v["cfg_override"] = cfg.scaled(moe_group=int(name[8:]))
    else:
        raise ValueError(f"unknown variant {name}")
    return v


def run_variant(arch: str, cell_name: str, variant: str,
                out_path: Optional[str] = None) -> Dict:
    cell = SHAPE_BY_NAME[cell_name]
    kw = variant_kwargs(variant, arch)
    rec = lower_cell(arch, cell, **kw)
    rec["variant"] = variant
    line = (f"{arch} x {cell_name} [{variant}]: "
            f"compute {rec['t_compute_s']:.3f}s  "
            f"memory {rec['t_memory_s']:.3f}s  "
            f"collective {rec['t_collective_s']:.3f}s  "
            f"-> {rec['bottleneck']}  mfu@roof {rec['mfu_at_roofline']:.3f}  "
            f"perdev {rec['per_device_gb']:.1f}GB "
            f"(compile {rec['compile_s']}s)")
    print(line, flush=True)
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", default="baseline", nargs="+")
    ap.add_argument("--out", default="results/perf_iterations.jsonl")
    args = ap.parse_args()
    for v in args.variant:
        try:
            run_variant(args.arch, args.cell, v, args.out)
        except Exception as e:                    # noqa: BLE001
            print(f"{args.arch} x {args.cell} [{v}]: FAILED {e}",
                  flush=True)


if __name__ == "__main__":
    main()
