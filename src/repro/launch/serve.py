"""Serving launcher: batched prefill + decode over the host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 32

Production notes: the same prefill/decode graphs lower against the
(16,16) / (2,16,16) production meshes in launch/dryrun.py; a fleet serving
deployment runs this driver per model replica with a front-end batcher
filling position-aligned batches.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models.nn import count_params
from repro.serving import engine as E


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count_params(params):,}")

    cache_len = args.cache_len or (args.prompt_len + args.new_tokens)
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    mem = None
    if cfg.family == "vlm":
        mem = jax.random.normal(key, (args.batch, cfg.n_img_tokens,
                                      cfg.d_model), jnp.float32)
    elif cfg.family == "encdec":
        mem = jax.random.normal(key, (args.batch, cfg.n_frames,
                                      cfg.d_model), jnp.float32)

    t0 = time.monotonic()
    logits, cc = jax.jit(
        lambda p, t, m: E.prefill(p, cfg, t, cache_len, memory=m)
    )(params, prompt, mem)
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.0f}ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")

    step = jax.jit(lambda p, c, t: E.decode_step(p, cfg, c, t))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    outs = [tok]
    t0 = time.monotonic()
    for _ in range(args.new_tokens - 1):
        lg, cc = step(params, cc, tok)
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.monotonic() - t0
    rate = args.batch * (args.new_tokens - 1) / max(t_dec, 1e-9)
    print(f"decode {args.new_tokens-1} steps: {t_dec*1e3:.0f}ms "
          f"({rate:.0f} tok/s, {t_dec/(args.new_tokens-1)*1e3:.1f} ms/step)")
    gen = np.asarray(jnp.concatenate(outs, axis=1))
    print(f"generated[0,:16] = {gen[0,:16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
