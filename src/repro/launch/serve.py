"""Serving launcher: continuous-batched generate over the shared scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --max-batch 4 --prompt-len 32 --new-tokens 32

Requests are submitted one prompt at a time — as a front end would
deliver them — and the :class:`repro.serving.GenerateDriver` packs them
into position-aligned batches on the SAME ``BatchScheduler`` layer the
stencil driver (`serving/stencil_driver.py`) uses for grid traffic, so
occupancy/latency/backpressure metrics mean the same thing for both
traffic classes.  A fleet deployment runs this per model replica.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models.nn import count_params
from repro.serving import BatchPolicy, GenerateDriver


def _request_stream(cfg, n_requests, prompt_len, seed=1):
    """Per-request prompts (and memories for vlm/encdec), like a front end."""
    key = jax.random.PRNGKey(seed)
    for i in range(n_requests):
        key, kp, km = jax.random.split(key, 3)
        prompt = jax.random.randint(kp, (prompt_len,), 0, cfg.vocab)
        mem = None
        if cfg.family == "vlm":
            mem = jax.random.normal(km, (cfg.n_img_tokens, cfg.d_model),
                                    jnp.float32)
        elif cfg.family == "encdec":
            mem = jax.random.normal(km, (cfg.n_frames, cfg.d_model),
                                    jnp.float32)
        yield prompt, mem


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=None,
                    help="number of single-prompt requests (default: batch)")
    ap.add_argument("--batch", type=int, default=4,
                    help="deprecated alias for --max-batch")
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count_params(params):,}")

    max_batch = args.max_batch or args.batch
    n_requests = args.requests or max_batch
    cache_len = args.cache_len or (args.prompt_len + args.new_tokens)
    policy = BatchPolicy(max_batch=max_batch, max_wait_ms=args.max_wait_ms)

    # autostart=False: enqueue the full wave first so the opening flush
    # already packs max_batch-sized aligned batches (steady-state shape).
    driver = GenerateDriver(params, cfg, cache_len=cache_len, policy=policy,
                            greedy=args.greedy, autostart=False)
    t0 = time.monotonic()
    futures = [driver.submit(prompt, args.new_tokens, memory=mem)
               for prompt, mem in _request_stream(cfg, n_requests,
                                                  args.prompt_len)]
    driver.start()
    results = [f.result() for f in futures]
    dt = time.monotonic() - t0
    driver.close()

    stats = driver.metrics()["overall"]
    tok = n_requests * args.new_tokens
    print(f"served {n_requests} requests ({tok} new tokens) in {dt*1e3:.0f}ms"
          f" ({tok/dt:.0f} tok/s)")
    print(f"batches={stats['batches']} occupancy={stats['batch_occupancy']}"
          f" p50={stats['latency']['p50_ms']:.0f}ms"
          f" p99={stats['latency']['p99_ms']:.0f}ms")
    gen = np.asarray(jnp.stack(results))
    print(f"generated[0,:16] = {gen[0, :16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
