"""Training launcher: end-to-end driver over the host mesh (CPU here, TPU
pods in production — identical code path, different mesh builder).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Restart the same command after a kill: it resumes from the newest atomic
checkpoint (fault-tolerance path exercised by tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed.sharding import default_rules, param_shardings, \
    use_mesh_rules
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.nn import axes_tree, count_params
from repro.training import (TrainConfig, TrainState, checkpoint as ckpt,
                            data, optimizer as O)
from repro.training.fault_tolerance import Watchdog
from repro.training.train_step import train_step


def make_world(cfg, tc, dc, mesh) -> Dict[str, Any]:
    """Build mesh-bound state + step fn + data fn for the CURRENT fleet."""
    rules = default_rules(fsdp=False, multi_pod=False)
    axes_store = {}

    def init_fn(key):
        params, axes = M.init_params(cfg, key)
        axes_store.update(axes)
        return params

    p_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    p_axes = axes_tree(p_shapes, axes_store)
    psh = param_shardings(p_axes, p_shapes, rules, mesh)
    rep = NamedSharding(mesh, P())
    st_sh = TrainState(params=psh,
                       opt=O.OptState(step=rep, mu=psh, nu=psh, master=psh))
    tok_sh = NamedSharding(mesh, P("data", None))

    with mesh:
        params = jax.jit(init_fn, out_shardings=psh)(jax.random.PRNGKey(0))
        state = TrainState(params=params, opt=O.init(params))

        def step(state, tokens):
            with use_mesh_rules(mesh, rules):
                return train_step(cfg, tc, state, tokens)

        step_c = jax.jit(step, in_shardings=(st_sh, tok_sh),
                         donate_argnums=(0,))

    return {"state": state, "state_shardings": st_sh, "step": step_c,
            "batch": lambda s: data.sharded_batch(dc, s, tok_sh),
            "mesh": mesh}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(microbatches=args.microbatches,
                     opt=O.OptConfig(lr=args.lr, warmup_steps=20,
                                     total_steps=args.steps))
    dc = data.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)
    mesh = make_host_mesh()
    world = make_world(cfg, tc, dc, mesh)
    state = world["state"]
    print(f"arch={cfg.name} params={count_params(state.params):,} "
          f"devices={jax.device_count()}")

    start = 0
    saver = None
    if args.ckpt_dir:
        saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            tree, extra = ckpt.restore(
                args.ckpt_dir, state.tree(),
                shardings={"params": world["state_shardings"].params,
                           "opt": world["state_shardings"].opt._asdict()})
            state = TrainState(params=tree["params"],
                               opt=O.OptState(**tree["opt"]))
            start = int(extra["step"])
            print(f"resumed from step {start}")

    wd = Watchdog()
    t_start = time.monotonic()
    for step in range(start, args.steps):
        t0 = time.monotonic()
        state, m = world["step"](state, world["batch"](step))
        loss = float(m["loss"])
        dt_step = time.monotonic() - t0
        straggle = wd.record(dt_step)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} {dt_step*1e3:.0f}ms"
                  + (" STRAGGLER" if straggle else ""), flush=True)
        if saver and (step + 1) % args.ckpt_every == 0:
            saver.save(step + 1, state.tree(), extra={"step": step + 1})
    if saver:
        saver.save(args.steps, state.tree(), extra={"step": args.steps})
        saver.wait()
    print(f"done in {time.monotonic()-t_start:.1f}s")
    return state


if __name__ == "__main__":
    main()
