from repro.models import layers, model, nn, ssm
__all__ = ["layers", "model", "nn", "ssm"]
