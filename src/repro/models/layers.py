"""Composable transformer components: norms, RoPE, GQA attention (plain,
blocked-flash, decode), MLPs, and capacity-based MoE.

Conventions:
  activations bf16 (cfg.dtype), softmax/norm statistics fp32;
  q/k/v laid out (B, S, H, Dh); GQA groups G = n_heads // n_kv_heads;
  logical axis names on params: embed, q_heads, kv_heads, head, mlp,
  experts, vocab.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.nn import ParamBuilder

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(pb: ParamBuilder, cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    p = {"scale": pb.param("scale", (d,), ("embed",), init="ones")}
    if cfg.norm == "ln":
        p["bias"] = pb.param("bias", (d,), ("embed",), init="zeros")
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * p["scale"].astype(jnp.float32)
    if cfg.norm == "ln":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps):
    """qk-norm (Qwen3): RMS over the head dim."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, d_rot: int):
    exp = np.arange(0, d_rot, 2, dtype=np.float64) / d_rot
    return jnp.asarray(1.0 / (cfg.rope_theta ** exp), jnp.float32)


def apply_rope(x, pos, cfg: ModelConfig):
    """x (..., S, H, D); pos (..., S) int32. Rotates the first
    rope_fraction * D dims (ChatGLM3's 2d-RoPE rotates half)."""
    d = x.shape[-1]
    d_rot = int(cfg.rope_fraction * d)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    freqs = rope_freqs(cfg, d_rot)                     # (d_rot/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, d_rot/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    rot = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(pb: ParamBuilder, cfg: ModelConfig, cross: bool = False):
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": pb.param("wq", (d, h, dh), ("embed", "q_heads", "head")),
        "wk": pb.param("wk", (d, kh, dh), ("embed", "kv_heads", "head")),
        "wv": pb.param("wv", (d, kh, dh), ("embed", "kv_heads", "head")),
        "wo": pb.param("wo", (h, dh, d), ("q_heads", "head", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = pb.param("q_norm", (dh,), ("head",), init="ones")
        p["k_norm"] = pb.param("k_norm", (dh,), ("head",), init="ones")
    if cross:
        p["gate"] = pb.param("gate", (), (), init="zeros")  # tanh-gated xattn
    return p


def _qkv(p, x, ctx, cfg: ModelConfig, q_pos, kv_pos, rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", ctx, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", ctx, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if rope and cfg.pos_emb == "rope":
        q = apply_rope(q, q_pos, cfg)
        k = apply_rope(k, kv_pos, cfg)
    return q, k, v


def blocked_attention(q, k, v, q_pos, kv_pos, *, causal: bool,
                      window: Optional[int], block_kv: int = 1024):
    """Flash-style attention: lax.scan over KV blocks with online softmax.

    q (B,S,H,D); k,v (B,T,Kh,D); positions int32. Memory O(S * block_kv)
    instead of O(S*T) — required for the 32k prefill cells.
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    qf = (q * scale).reshape(b, s, kh, g, d)

    nblk = -(-t // block_kv)
    t_pad = nblk * block_kv
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, t_pad - t)),
                         constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(b, nblk, block_kv, kh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block_kv, kh, d).transpose(1, 0, 2, 3, 4)
    pb_ = kv_pos.reshape(b, nblk, block_kv).transpose(1, 0, 2)

    m0 = jnp.full((b, s, kh, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, s, kh, g), jnp.float32)
    a0 = jnp.zeros((b, s, kh, g, d), jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk                       # (B,bk,Kh,D), (B,bk)
        sc = jnp.einsum("bskgd,btkd->bskgt", qf, kc,
                        preferred_element_type=jnp.float32)
        msk = jnp.ones((b, s, 1, 1, kc.shape[1]), bool)
        if causal:
            msk &= (pc[:, None, None, None, :] <= q_pos[:, :, None, None, None])
        if window is not None:
            msk &= (pc[:, None, None, None, :] >
                    q_pos[:, :, None, None, None] - window)
        msk &= (pc != jnp.iinfo(jnp.int32).max)[:, None, None, None, :]
        sc = jnp.where(msk, sc, -jnp.inf)
        m_new = jnp.maximum(m, sc.max(-1))
        # guard all-masked rows
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        pexp = jnp.exp(sc - m_safe[..., None])
        pexp = jnp.where(msk, pexp, 0.0)
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + pexp.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", pexp.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb_))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(b, s, h, d).astype(q.dtype)


def banded_attention(q, k, v, q_pos, kv_pos, *, window: int,
                     block_q: int = 512):
    """Sliding-window attention that SKIPS out-of-band KV — the paper's
    structural-sparsity insight applied one level up (§Perf optimization).

    For a query chunk [qs, qs+Bq) under a causal window W, the entire
    receptive field lies in kv[qs+Bq-L, qs+Bq) with static L = Bq + W, so
    each chunk needs ONE end-aligned dynamic slice and ONE exact softmax —
    no online-softmax carry, no O(S/Bkv) scan over masked-out blocks.
    Compute and KV traffic drop from O(S^2) to O(S(W+Bq)).

    Requires contiguous positions (train/prefill self-attention).
    q (B,S,H,D); k,v (B,T,Kh,D). Returns (B,S,H,D).
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    L = block_q + window
    nq = -(-s // block_q)
    s_pad = nq * block_q
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, s_pad - s)),
                        constant_values=jnp.iinfo(jnp.int32).max - 1)
    if t < L:                                   # left-pad so slices exist
        k = jnp.pad(k, ((0, 0), (L - t, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (L - t, 0), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (L - t, 0)),
                         constant_values=-1)
    qf = (q * scale).reshape(b, nq, block_q, kh, g, d).transpose(
        1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(b, nq, block_q).transpose(1, 0, 2)

    def chunk(i, qc, qpc):
        # end-aligned band; clamp explicitly (traced negative starts WRAP
        # in dynamic_slice, they do not clamp)
        start = jnp.clip(i * block_q + block_q - L, 0, k.shape[1] - L)
        kc = jax.lax.dynamic_slice_in_dim(k, start, L, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, L, axis=1)
        pc = jax.lax.dynamic_slice_in_dim(kv_pos, start, L, axis=1)
        sc = jnp.einsum("bskgd,btkd->bskgt", qc, kc,
                        preferred_element_type=jnp.float32)
        msk = (pc[:, None, None, None, :] <= qpc[:, :, None, None, None])
        msk &= (pc[:, None, None, None, :] >
                qpc[:, :, None, None, None] - window)
        msk &= (pc >= 0)[:, None, None, None, :]
        sc = jnp.where(msk, sc, -jnp.inf)
        mx = jnp.max(sc, axis=-1, keepdims=True)
        mx = jnp.where(jnp.isinf(mx), 0.0, mx)
        p = jnp.exp(sc - mx)
        p = jnp.where(msk, p, 0.0)
        l = jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
        return jnp.einsum("bskgt,btkd->bskgd", (p / l).astype(vc.dtype), vc,
                          preferred_element_type=jnp.float32)

    def body(_, xs):
        i, qc, qpc = xs
        return None, chunk(i, qc, qpc)

    _, out = jax.lax.scan(body, None,
                          (jnp.arange(nq, dtype=jnp.int32), qf, qp))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s_pad, h, d)
    return out[:, :s].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, q_pos, kv_pos, *, window,
                     causal: bool = True):
    """Single-token attention over a cache. q (B,1,H,D); caches (B,T,Kh,D).

    causal=False (cross-attention over encoder/image memory) masks only
    invalid (kv_pos < 0) slots.
    """
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    qf = (q * (1.0 / math.sqrt(d))).reshape(b, 1, kh, g, d)
    sc = jnp.einsum("bskgd,btkd->bskgt", qf, k_cache,
                    preferred_element_type=jnp.float32)
    msk = kv_pos[:, None, None, None, :] >= 0
    if causal:
        msk &= kv_pos[:, None, None, None, :] <= q_pos[:, :, None, None, None]
    if window is not None:
        msk &= (kv_pos[:, None, None, None, :] >
                q_pos[:, :, None, None, None] - window)
    sc = jnp.where(msk, sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention_core(q, k, v, q_pos, kv_pos, cfg: ModelConfig, *,
                   causal: bool, block_kv: int = 1024):
    """Dispatch: banded SWA fast path (when enabled) or blocked/flash."""
    window = cfg.sliding_window if causal else None
    if (causal and window and cfg.banded_attention
            and q.shape[1] > 1 and q.shape[1] == k.shape[1]):
        return banded_attention(q, k, v, q_pos, kv_pos, window=window,
                                block_q=cfg.attn_block_q)
    return blocked_attention(q, k, v, q_pos, kv_pos, causal=causal,
                             window=window, block_kv=block_kv)


def attention(p, x, cfg: ModelConfig, *, q_pos, ctx=None, kv_pos=None,
              causal=True, rope=True, block_kv: int = 1024):
    """Full (self- or cross-) attention for train/prefill."""
    ctx_in = x if ctx is None else ctx
    if kv_pos is None:
        kv_pos = q_pos
    q, k, v = _qkv(p, x, ctx_in, cfg, q_pos, kv_pos, rope)
    o = attention_core(q, k, v, q_pos, kv_pos, cfg, causal=causal,
                       block_kv=block_kv)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(y.dtype)) * y
    return y


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(pb: ParamBuilder, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w1": pb.param("w1", (d, f), ("embed", "mlp")),
            "w3": pb.param("w3", (d, f), ("embed", "mlp")),
            "w2": pb.param("w2", (f, d), ("mlp", "embed")),
        }
    return {
        "w1": pb.param("w1", (d, f), ("embed", "mlp")),
        "w2": pb.param("w2", (f, d), ("mlp", "embed")),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["w1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-based einsum dispatch — GShard/MaxText style)
# ---------------------------------------------------------------------------

def init_moe(pb: ParamBuilder, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": pb.param("router", (d, e), ("embed", "experts")),
        "w1": pb.param("w1", (e, d, f), ("experts", "embed", "mlp")),
        "w3": pb.param("w3", (e, d, f), ("experts", "embed", "mlp")),
        "w2": pb.param("w2", (e, f, d), ("experts", "mlp", "embed")),
    }
    return p


def moe_capacity(cfg: ModelConfig, group: int) -> int:
    cap = int(math.ceil(group * cfg.top_k * cfg.capacity_factor
                        / cfg.n_experts))
    return max(8, -(-cap // 8) * 8)


def apply_moe(p, x, cfg: ModelConfig):
    """x (B, S, D) -> (B, S, D), plus aux load-balancing loss.

    Tokens are routed in groups of cfg.moe_group (bounds the dispatch
    tensor); overflow beyond expert capacity is dropped (capacity_factor).
    Dispatch/combine are one-hot einsums — under EP sharding these lower to
    all-to-all collectives.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    nt = b * s
    grp = min(cfg.moe_group, nt)
    n_grp = -(-nt // grp)
    pad = n_grp * grp - nt
    xf = x.reshape(nt, d)
    if pad:                     # pad tokens fill the tail dispatch group
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xt = xf.reshape(n_grp, grp, d)

    logits = jnp.einsum("gnd,de->gne", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                    # (g,n,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = moe_capacity(cfg, grp)
    ddt = jnp.bfloat16 if cfg.moe_dispatch_dtype == "bfloat16" \
        else jnp.float32
    sel = jax.nn.one_hot(idx, e, dtype=jnp.float32)             # (g,n,k,e)
    # position of each (token, choice) in its expert's buffer
    pos_in_exp = (jnp.cumsum(sel.reshape(n_grp, grp * k, e), axis=1)
                  .reshape(n_grp, grp, k, e) - 1.0)
    keep = (pos_in_exp < cap) & (sel > 0)
    pos_oh = jax.nn.one_hot(pos_in_exp.astype(jnp.int32), cap,
                            dtype=ddt) * keep[..., None].astype(ddt)
    disp = pos_oh.sum(2)                                         # (g,n,e,c)
    comb = jnp.einsum("gnke,gnkec->gnec",
                      (gate_vals[..., None] * keep).astype(ddt), pos_oh,
                      preferred_element_type=jnp.float32)

    xe = jnp.einsum("gnec,gnd->gecd", disp.astype(x.dtype), xt)  # (g,e,c,d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w1"].astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w3"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(x.dtype))
    y = jnp.einsum("gnec,gecd->gnd", comb.astype(x.dtype), ye)

    # Switch-style load-balancing aux loss
    me = probs.mean(axis=1)                                      # (g,e)
    ce = sel.sum(2).mean(axis=1)                                 # (g,e)
    aux = (me * ce).sum(-1).mean() * e
    y = y.reshape(n_grp * grp, d)
    if pad:
        y = y[:nt]
    return y.reshape(b, s, d), aux
