"""Full-model assembly for the 10 assigned architectures.

One functional LM covering six families:

  dense    [norm->attn, norm->mlp] x L                (starcoder2, chatglm3,
                                                       qwen3, phi3)
  moe      [norm->attn, norm->moe] x L                (granite-moe, mixtral)
  ssm      [norm->mamba2] x L                         (mamba2)
  hybrid   groups of `attn_every` mamba layers + one  (zamba2)
           weight-SHARED attention/MLP block applied
           between groups
  encdec   encoder [norm->bidi-attn, norm->mlp] x Le  (whisper; conv frontend
           decoder [self, cross, mlp] x L              stubbed to frame embeds)
  vlm      groups of `cross_attn_every` self layers   (llama-3.2-vision; patch
           with one gated cross-attn layer per group   embeds stubbed)

All homogeneous stacks run under ``lax.scan`` over stacked layer params
(models/nn.stack_init) — keeping the lowered HLO size independent of depth,
which is what makes the 80-cell dry-run sweep compile in reasonable time and
what a real 1000-node deployment wants anyway (single compiled layer body).

Activation sharding uses logical names via distributed.sharding.constrain —
a no-op outside a mesh context (smoke tests), binding inside dryrun/train.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.nn import ParamBuilder, stack_init

Params = Dict[str, Any]


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_dense_layer(pb: ParamBuilder, cfg: ModelConfig) -> Params:
    p = {
        "attn_norm": L.init_norm(pb.sub("attn_norm"), cfg),
        "attn": L.init_attention(pb.sub("attn"), cfg),
        "mlp_norm": L.init_norm(pb.sub("mlp_norm"), cfg),
    }
    if cfg.family == "moe" or (cfg.n_experts > 0):
        p["moe"] = L.init_moe(pb.sub("moe"), cfg)
    else:
        p["mlp"] = L.init_mlp(pb.sub("mlp"), cfg)
    return p


def _init_cross_layer(pb: ParamBuilder, cfg: ModelConfig) -> Params:
    return {
        "attn_norm": L.init_norm(pb.sub("attn_norm"), cfg),
        "attn": L.init_attention(pb.sub("attn"), cfg, cross=True),
        "mlp_norm": L.init_norm(pb.sub("mlp_norm"), cfg),
        "mlp": L.init_mlp(pb.sub("mlp"), cfg),
    }


def _init_mamba_layer(pb: ParamBuilder, cfg: ModelConfig) -> Params:
    return {
        "norm": L.init_norm(pb.sub("norm"), cfg),
        "mamba": S.init_mamba(pb.sub("mamba"), cfg),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Dict]:
    """Build the full parameter pytree + logical-axes dict.

    Runs under jax.eval_shape for the dry-run (no allocation).
    """
    pb = ParamBuilder(key, dtype=_dt(cfg))
    p: Params = {
        "embed": pb.param("embed", (cfg.vocab, cfg.d_model),
                          ("vocab", "embed"), scale=0.02),
        "final_norm": L.init_norm(pb.sub("final_norm"), cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = pb.param("lm_head", (cfg.d_model, cfg.vocab),
                                ("embed", "vocab"))
    if cfg.pos_emb == "learned":
        p["pos"] = pb.param("pos", (cfg.max_seq, cfg.d_model),
                            ("seq", "embed"), scale=0.02)

    fam = cfg.family
    if fam in ("dense", "moe"):
        p["layers"] = stack_init(
            lambda b, i: _init_dense_layer(b.sub("layers"), cfg),
            cfg.n_layers, pb)
    elif fam == "ssm":
        p["layers"] = stack_init(
            lambda b, i: _init_mamba_layer(b.sub("layers"), cfg),
            cfg.n_layers, pb)
    elif fam == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        assert ng * cfg.attn_every == cfg.n_layers, "attn_every | n_layers"
        # (ng, every, ...) nested stack of mamba layers
        p["groups"] = stack_init(
            lambda b, i: stack_init(
                lambda b2, j: _init_mamba_layer(b2.sub("groups"), cfg),
                cfg.attn_every, b),
            ng, pb)
        # ONE weight-shared attention block (Zamba2's shared transformer)
        p["shared"] = _init_dense_layer(pb.sub("shared"), cfg)
    elif fam == "vlm":
        ng = cfg.n_layers // cfg.cross_attn_every
        assert ng * cfg.cross_attn_every == cfg.n_layers
        p["groups"] = stack_init(
            lambda b, i: stack_init(
                lambda b2, j: _init_dense_layer(b2.sub("groups"), cfg),
                cfg.cross_attn_every, b),
            ng, pb)
        p["cross"] = stack_init(
            lambda b, i: _init_cross_layer(b.sub("cross"), cfg), ng, pb)
    elif fam == "encdec":
        p["enc_pos"] = pb.param("enc_pos", (cfg.n_frames, cfg.d_model),
                                ("seq", "embed"), scale=0.02)
        p["enc_layers"] = stack_init(
            lambda b, i: _init_dense_layer(b.sub("enc_layers"), cfg),
            cfg.n_enc_layers, pb)
        p["enc_norm"] = L.init_norm(pb.sub("enc_norm"), cfg)
        p["dec_layers"] = stack_init(
            lambda b, i: {
                **_init_dense_layer(b.sub("dec_layers"), cfg),
                "cross_norm": L.init_norm(
                    b.sub("dec_layers").sub("cross_norm"), cfg),
                "cross": L.init_attention(
                    b.sub("dec_layers").sub("cross"), cfg),
            },
            cfg.n_layers, pb)
    else:
        raise ValueError(f"unknown family {fam}")
    return p, pb.axes


# ---------------------------------------------------------------------------
# block bodies (train / prefill form; also emit K/V for cache build)
# ---------------------------------------------------------------------------

def _dense_block(p, x, cfg, q_pos, *, collect_kv=False):
    hn = L.apply_norm(p["attn_norm"], x, cfg)
    ctx_kv = None
    if collect_kv:
        q, k, v = L._qkv(p["attn"], hn, hn, cfg, q_pos, q_pos, True)
        o = L.attention_core(q, k, v, q_pos, q_pos, cfg, causal=True,
                             block_kv=cfg.attn_block_kv)
        y = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(o.dtype))
        ctx_kv = (k, v)
    else:
        y = L.attention(p["attn"], hn, cfg, q_pos=q_pos,
                        block_kv=cfg.attn_block_kv)
    x = x + y
    x = constrain(x, ("batch", "seq", "embed"))
    h = L.apply_norm(p["mlp_norm"], x, cfg)
    aux = 0.0
    if "moe" in p:
        y, aux = L.apply_moe(p["moe"], h, cfg)
    else:
        y = L.apply_mlp(p["mlp"], h, cfg)
    x = x + y
    x = constrain(x, ("batch", "seq", "embed"))
    return x, aux, ctx_kv


def _mamba_block(p, x, cfg, *, collect_state=False):
    h = L.apply_norm(p["norm"], x, cfg)
    if collect_state:
        y, st = S.apply_mamba(p["mamba"], h, cfg, return_state=True)
        return constrain(x + y, ("batch", "seq", "embed")), st
    x = x + S.apply_mamba(p["mamba"], h, cfg)
    return constrain(x, ("batch", "seq", "embed")), None


def _cross_block(p, x, mem, cfg, q_pos, mem_pos, *, gated=True):
    h = L.apply_norm(p["attn_norm"], x, cfg)
    y = L.attention(p["attn"], h, cfg, q_pos=q_pos, ctx=mem, kv_pos=mem_pos,
                    causal=False, rope=False, block_kv=cfg.attn_block_kv)
    x = x + y
    if "mlp" in p:
        h = L.apply_norm(p["mlp_norm"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], h, cfg)
    return constrain(x, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_tokens(p, cfg, tokens):
    x = jnp.take(p["embed"], tokens, axis=0).astype(_dt(cfg))
    if cfg.pos_emb == "learned":
        s = tokens.shape[1]
        x = x + p["pos"][:s][None].astype(x.dtype)
    return constrain(x, ("batch", "seq", "embed"))


def unembed(p, cfg, x):
    x = L.apply_norm(p["final_norm"], x, cfg)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return constrain(logits, ("batch", "seq", "vocab"))


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            memory: Optional[jnp.ndarray] = None,
            collect_kv: bool = False):
    """tokens (B, S) -> logits (B, S, V) fp32 [+ aux losses + caches].

    memory: encdec -> frame embeddings (B, F, D); vlm -> patch embeddings
    (B, I, D). Both are frontend STUBS per the assignment.

    Returns (logits, aux, kv): kv is a dict of stacked per-layer K/V (for
    prefill cache construction) when collect_kv, else None.
    """
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    fam = cfg.family
    aux_total = 0.0
    kv_out: Dict[str, Any] = {}

    if fam in ("dense", "moe"):
        def body(carry, pl_):
            x, aux = carry
            x, a, kv = _dense_block(pl_, x, cfg, q_pos, collect_kv=collect_kv)
            return (x, aux + a), kv
        body = _remat(body, cfg)
        (x, aux_total), kvs = jax.lax.scan(body, (x, 0.0), params["layers"])
        if collect_kv:
            kv_out["self"] = kvs                      # (L, B, S, Kh, Dh) x2

    elif fam == "ssm":
        def body(x, pl_):
            return _mamba_block(pl_, x, cfg, collect_state=collect_kv)
        body = _remat(body, cfg)
        x, states = jax.lax.scan(body, x, params["layers"])
        if collect_kv:
            kv_out["states"] = states                 # (L, B, ...) dicts

    elif fam == "hybrid":
        shared = params["shared"]

        def group(carry, gp):
            x, aux = carry

            def inner(xc, pl_):
                return _mamba_block(pl_, xc, cfg, collect_state=collect_kv)
            x, states = jax.lax.scan(inner, x, gp)
            x, a, kv = _dense_block(shared, x, cfg, q_pos,
                                    collect_kv=collect_kv)
            return (x, aux + a), (kv, states)
        group = _remat(group, cfg)
        (x, aux_total), (kvs, states) = jax.lax.scan(
            group, (x, 0.0), params["groups"])
        if collect_kv:
            kv_out["shared"] = kvs                    # (G, B, S, Kh, Dh) x2
            kv_out["states"] = jax.tree.map(         # (G, every, ...) -> (L, ...)
                lambda a: a.reshape((-1,) + a.shape[2:]), states)

    elif fam == "vlm":
        mem = memory.astype(x.dtype)
        i_pos = jnp.broadcast_to(
            jnp.arange(mem.shape[1], dtype=jnp.int32)[None], mem.shape[:2])

        def group(carry, gp):
            x, aux = carry
            cp, sp = gp
            x = _cross_block(cp, x, mem, cfg, q_pos, i_pos)

            def inner(c, pl_):
                xc, a = c
                xc, ai, kv = _dense_block(pl_, xc, cfg, q_pos,
                                          collect_kv=collect_kv)
                return (xc, a + ai), kv
            (x, aux), kvs = jax.lax.scan(inner, (x, aux), sp)
            return (x, aux), kvs
        group = _remat(group, cfg)
        (x, aux_total), kvs = jax.lax.scan(
            group, (x, 0.0), (params["cross"], params["groups"]))
        if collect_kv:
            kv_out["self"] = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]), kvs)

    elif fam == "encdec":
        mem = encode(params, cfg, memory)
        m_pos = jnp.broadcast_to(
            jnp.arange(mem.shape[1], dtype=jnp.int32)[None], mem.shape[:2])

        def body(carry, pl_):
            x, aux = carry
            h = L.apply_norm(pl_["attn_norm"], x, cfg)
            if collect_kv:
                q, k, v = L._qkv(pl_["attn"], h, h, cfg, q_pos, q_pos, False)
                o = L.blocked_attention(q, k, v, q_pos, q_pos, causal=True,
                                        window=None,
                                        block_kv=cfg.attn_block_kv)
                y = jnp.einsum("bshk,hkd->bsd", o,
                               pl_["attn"]["wo"].astype(o.dtype))
                kv = (k, v)
            else:
                y = L.attention(pl_["attn"], h, cfg, q_pos=q_pos, rope=False,
                                block_kv=cfg.attn_block_kv)
                kv = None
            x = x + y
            h = L.apply_norm(pl_["cross_norm"], x, cfg)
            x = x + L.attention(pl_["cross"], h, cfg, q_pos=q_pos, ctx=mem,
                                kv_pos=m_pos, causal=False, rope=False,
                                block_kv=cfg.attn_block_kv)
            h = L.apply_norm(pl_["mlp_norm"], x, cfg)
            x = x + L.apply_mlp(pl_["mlp"], h, cfg)
            x = constrain(x, ("batch", "seq", "embed"))
            return (x, aux), kv
        body = _remat(body, cfg)
        (x, aux_total), kvs = jax.lax.scan(body, (x, 0.0),
                                           params["dec_layers"])
        if collect_kv:
            kv_out["self"] = kvs
            kv_out["memory"] = mem
    else:
        raise ValueError(fam)

    logits = unembed(params, cfg, x)
    return logits, aux_total, (kv_out if collect_kv else None)


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray):
    """Whisper encoder over stubbed frame embeddings (B, F, D)."""
    x = frames.astype(_dt(cfg)) + params["enc_pos"][None].astype(_dt(cfg))
    x = constrain(x, ("batch", "seq", "embed"))
    b, f = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))

    def body(x, pl_):
        h = L.apply_norm(pl_["attn_norm"], x, cfg)
        x = x + L.attention(pl_["attn"], h, cfg, q_pos=pos, causal=False,
                            rope=False, block_kv=cfg.attn_block_kv)
        h = L.apply_norm(pl_["mlp_norm"], x, cfg)
        x = x + L.apply_mlp(pl_["mlp"], h, cfg)
        return constrain(x, ("batch", "seq", "embed")), None
    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], x, cfg)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            memory: Optional[jnp.ndarray] = None,
            aux_weight: float = 0.01):
    """Next-token cross-entropy (fp32 logits) + MoE aux loss."""
    logits, aux, _ = forward(params, cfg, tokens[:, :-1], memory=memory)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}
