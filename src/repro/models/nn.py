"""Minimal functional module system: param pytrees + logical-axis metadata.

No flax dependency. Parameters are nested dicts of arrays; every leaf has a
tuple of *logical axis names* recorded in a parallel tree during init. The
distribution layer (distributed/sharding.py) maps logical names to mesh axes
(MaxText-style logical-axis rules), so a config can flip DP/FSDP/TP/EP without
touching model code.

Init functions run under ``jax.eval_shape`` for the dry-run — no allocation.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Axes = Tuple[str | None, ...]


class ParamBuilder:
    """Creates parameters with deterministic per-path RNG and records axes."""

    def __init__(self, key: jax.Array, dtype=jnp.float32, path: str = "",
                 axes: dict | None = None):
        self._key = key
        self.dtype = dtype
        self._path = path
        # the axes dict is SHARED by all sub-builders; keys are /-paths
        self.axes: Dict[str, Axes] = axes if axes is not None else {}

    def sub(self, name: str) -> "ParamBuilder":
        return ParamBuilder(self._key, self.dtype,
                            f"{self._path}/{name}", self.axes)

    def layer(self, i) -> "ParamBuilder":
        """Per-layer builder: distinct RNG stream, *same* path (for scan
        stacking the axes are recorded once, identically across layers)."""
        return ParamBuilder(jax.random.fold_in(self._key, i), self.dtype,
                            self._path, self.axes)

    def _fold(self, name: str) -> jax.Array:
        path = f"{self._path}/{name}"
        h = np.uint32(np.frombuffer(
            path.encode(), dtype=np.uint8).sum() * 2654435761 % (2**31))
        return jax.random.fold_in(self._key, h)

    def param(self, name: str, shape: Tuple[int, ...], axes: Axes,
              init: str = "normal", scale: float | None = None) -> jax.Array:
        if len(axes) != len(shape):
            raise ValueError(f"{self._path}/{name}: axes {axes} vs shape {shape}")
        self.axes[f"{self._path}/{name}"] = axes
        k = self._fold(name)
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if scale is None:
            # fan-in scaling over contracted (leading) dims
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 1.0 / np.sqrt(max(1, fan_in))
        return (jax.random.normal(k, shape) * scale).astype(self.dtype)


def tree_paths(tree: Params, prefix: str = "") -> Dict[str, Any]:
    out = {}
    for k, v in tree.items():
        p = f"{prefix}/{k}"
        if isinstance(v, dict):
            out.update(tree_paths(v, p))
        else:
            out[p] = v
    return out


def axes_tree(params: Params, axes: Dict[str, Axes]) -> Params:
    """Build a tree with the same structure as ``params`` holding axis tuples.

    Stacked (scanned) layer params get a leading 'layers' axis automatically
    when the recorded tuple is one shorter than the array rank.
    """
    def rec(tree: Params, prefix: str) -> Params:
        out = {}
        for k, v in tree.items():
            p = f"{prefix}/{k}"
            if isinstance(v, dict):
                out[k] = rec(v, p)
            else:
                ax = axes.get(p)
                if ax is None:
                    raise KeyError(f"no axes recorded for {p}")
                ax = tuple(ax)
                while len(ax) < v.ndim:       # stacked (scanned) layer dims
                    ax = ("layers",) + ax
                if len(ax) != v.ndim:
                    raise ValueError(f"{p}: rank {v.ndim} vs axes {ax}")
                out[k] = tuple(ax)
        return out
    return rec(params, "")


def stack_init(init_one: Callable[[ParamBuilder, int], Params], n: int,
               pb: ParamBuilder) -> Params:
    """Initialize ``n`` structurally-identical layers stacked on axis 0.

    The per-layer init runs under vmap over the layer index so the result is
    a single pytree with a leading (n, ...) axis — the form lax.scan consumes.
    """
    return jax.vmap(lambda i: init_one(pb.layer(i), i))(jnp.arange(n))


def count_params(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
