"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training uses the chunked SSD algorithm: within-chunk "attention form"
(C B^T masked by cumulative decays) + an inter-chunk recurrent state pass —
O(T Q) memory instead of O(T^2) or O(T·P·N) materialized states.
Decoding is the O(1) recurrence on a (H, P, N) state.

The depthwise causal conv (width 4) over (x, B, C) is a per-channel 1-D
stencil — the framework integration point of the paper's technique
(kernels/conv1d; cfg.use_pallas switches the Pallas kernel in-graph).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.nn import ParamBuilder


def init_mamba(pb: ParamBuilder, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = di + 2 * n                   # x, B, C share the conv (groups=1)
    return {
        "in_proj": pb.param("in_proj", (d, 2 * di + 2 * n + h),
                            ("embed", "mlp")),
        "conv_w": pb.param("conv_w", (cfg.conv_width, conv_ch),
                           ("conv", "mlp")),
        "conv_b": pb.param("conv_b", (conv_ch,), ("mlp",), init="zeros"),
        "a_log": pb.param("a_log", (h,), ("heads",), init="zeros"),
        "dt_bias": pb.param("dt_bias", (h,), ("heads",), init="zeros"),
        "D": pb.param("D", (h,), ("heads",), init="ones"),
        "norm": pb.param("norm", (di,), ("mlp",), init="ones"),
        "out_proj": pb.param("out_proj", (di, d), ("mlp", "embed")),
    }


def _split(cfg: ModelConfig, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _conv(p, xbc, cfg: ModelConfig):
    if cfg.use_pallas:
        from repro.kernels.conv1d.ops import conv1d_causal
        y = conv1d_causal(xbc, p["conv_w"].astype(xbc.dtype))
    else:
        from repro.kernels.conv1d.ref import conv1d_causal_ref
        y = conv1d_causal_ref(xbc, p["conv_w"].astype(xbc.dtype))
    return jax.nn.silu(y + p["conv_b"].astype(xbc.dtype))


def ssd_chunked(x, dt, a_log, B, C, D, chunk: int):
    """Chunked SSD scan.

    x (b,t,h,p); dt (b,t,h) (post-softplus); a_log (h); B,C (b,t,n); D (h).
    Returns (y (b,t,h,p), final_state (b,h,p,n)).

    Padded tail positions carry dt = 0 (pad after softplus), so they neither
    decay nor feed the state — the returned final_state is exact, which the
    prefill -> decode handoff relies on.
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, t)
    nc = -(-t // q)
    pad = nc * q - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)

    la = -jnp.exp(a_log.astype(jnp.float32)) * dtc        # (b,nc,q,h) log-decay
    cum = jnp.cumsum(la, axis=2)

    # ---- intra-chunk (attention form) ----
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                    preferred_element_type=jnp.float32)   # (b,nc,q,q)
    li = cum[:, :, :, None, :]                            # i index
    lj = cum[:, :, None, :, :]                            # j index
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))        # (b,nc,q,q,h)
    mask = jnp.tril(jnp.ones((q, q), bool))
    att = cb[..., None] * decay * dtc[:, :, None, :, :]   # weight by dt_j
    att = jnp.where(mask[None, None, :, :, None], att, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc.astype(jnp.float32))

    # ---- chunk states ----
    last = cum[:, :, -1:, :]                              # (b,nc,1,h)
    w = jnp.exp(jnp.clip(last - cum, -60.0, None)) * dtc  # (b,nc,q,h)
    S = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", w, Bc.astype(jnp.float32),
                   xc.astype(jnp.float32))                # (b,nc,h,p,n)
    A_chunk = jnp.exp(jnp.clip(last[:, :, 0, :], -60.0, 0.0))  # (b,nc,h)

    def step(carry, inp):
        s_new, a_c = inp                                  # (b,h,p,n), (b,h)
        out = carry
        carry = carry * a_c[:, :, None, None] + s_new
        return carry, out

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, states_prev = jax.lax.scan(
        step, s0, (S.transpose(1, 0, 2, 3, 4), A_chunk.transpose(1, 0, 2)))
    states_prev = states_prev.transpose(1, 0, 2, 3, 4)    # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc.astype(jnp.float32),
                         states_prev, jnp.exp(jnp.clip(cum, -60.0, 0.0)))
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :t]
    y = y + D.astype(jnp.float32)[None, None, :, None] * \
        x[:, :t].astype(jnp.float32)
    return y, final


def apply_mamba(p, x, cfg: ModelConfig, return_state: bool = False):
    """Training/prefill forward. x (B,T,D) -> (B,T,D) [, decode state]."""
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xbc_raw, dt = _split(cfg, proj)
    xbc = _conv(p, xbc_raw, cfg)
    xs = xbc[..., :di]
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(*xs.shape[:-1], h, hd)
    y, final = ssd_chunked(xh, dt, p["a_log"], B, C, p["D"], cfg.ssm_chunk)
    y = y.reshape(*xs.shape).astype(x.dtype)
    y = y * jax.nn.silu(z)
    # grouped RMS norm over d_inner
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    if not return_state:
        return out
    # conv rolling buffer = last (K-1) *raw* conv inputs, left-zero padded
    kb = cfg.conv_width - 1
    t = xbc_raw.shape[1]
    buf = jnp.pad(xbc_raw, ((0, 0), (max(0, kb - t), 0), (0, 0)))[:, -kb:]
    return out, {"ssm": final, "conv": buf}


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, hd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner
    return {
        "ssm": jnp.zeros((batch, h, hd, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
    }


def apply_mamba_decode(p, x, state, cfg: ModelConfig):
    """Single-token step. x (B,1,D); state dict -> (y (B,1,D), state)."""
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split(cfg, proj)
    # conv over rolling buffer
    buf = jnp.concatenate([state["conv"], xbc], axis=1)   # (B,K,ch)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", buf, w)[:, None, :]
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(x.dtype))
    new_conv = buf[:, 1:]
    xs = xbc[..., :di]
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))[:, 0]   # (B,h)
    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32)) * dt)     # (B,h)
    xh = xs.reshape(-1, h, hd).astype(jnp.float32)                 # (B,h,hd)
    inc = dt[:, :, None, None] * xh[..., None] * \
        B[:, 0].astype(jnp.float32)[:, None, None, :]              # (B,h,hd,n)
    s = state["ssm"] * a[:, :, None, None] + inc
    y = jnp.einsum("bhpn,bn->bhp", s, C[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype), {"ssm": s, "conv": new_conv}
