from repro.roofline.analysis import (Roofline, collective_bytes,
                                     from_compiled, model_flops_for_cell,
                                     PEAK_FLOPS, HBM_BW, LINK_BW)
__all__ = ["Roofline", "collective_bytes", "from_compiled",
           "model_flops_for_cell", "PEAK_FLOPS", "HBM_BW", "LINK_BW"]
