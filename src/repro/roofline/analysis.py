"""Three-term roofline from a compiled dry-run artifact (no hardware).

  compute    = HLO_FLOPs   / (chips x peak_FLOP/s)
  memory     = HLO_bytes   / (chips x HBM_bw)
  collective = coll_bytes  / (chips x link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs / bytes-accessed;
collective bytes are NOT in cost_analysis, so we parse the *optimized,
partitioned* HLO (``compiled.as_text()``) and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
The partitioned module is the per-device program, so parsed byte counts are
per-chip; cost_analysis of that module is likewise per-chip — both are
converted to the global quantities the formulas above expect.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# shape tokens like f32[128,512] or bf16[8,128]{1,0}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line: "%name = <shape(s)> opcode(...)" — opcode may be
# prefixed (e.g. all-reduce-start) for async collectives.
_INSTR_RE = re.compile(
    r"=\s*(?:\(?)((?:[a-z0-9]+\[[0-9,]*\][^)]*?,?\s*)+)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-op byte totals (per-device program).

    Counts the RESULT shape bytes of each collective instruction (== operand
    bytes for all-reduce / permute / all-to-all; for all-gather the result
    is the gathered tensor, for reduce-scatter the operand is the
    pre-scatter tensor — we count the LARGER side, the wire-dominant one).
    """
    out: Dict[str, int] = {op: 0 for op in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = None
        for op in COLLECTIVES:
            if f" {op}(" in line or f" {op}-start(" in line:
                m = op
                break
        if m is None:
            continue
        # result type(s): between '=' and the opcode token
        eq = line.find("=")
        op_pos = line.find(f" {m}")
        if eq < 0 or op_pos < eq:
            continue
        result_part = line[eq + 1:op_pos]
        nbytes = _shape_bytes(result_part)
        if m == "reduce-scatter":
            # operand (pre-scatter) dominates the wire; parse operand shapes
            operand_part = line[op_pos:]
            ob = _shape_bytes(operand_part)
            nbytes = max(nbytes, ob)
        out[m] += nbytes
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    arch: str
    cell: str
    mesh: str
    chips: int
    # global quantities
    flops: float                 # HLO FLOPs (all chips)
    hbm_bytes: float             # HLO bytes accessed (all chips)
    coll_bytes: float            # collective bytes (all chips)
    coll_by_op: Dict[str, int]   # per-device, by op
    # analytic
    model_flops: float           # 6 * N(_active) * D
    # memory footprint
    per_device_bytes: int
    # raw cost_analysis reference (per-device, scan bodies counted once)
    raw_flops: float = 0.0
    raw_bytes: float = 0.0
    top_collectives: tuple = ()

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        t = self.step_time
        return (self.model_flops / t) / (self.chips * PEAK_FLOPS) if t else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_gflops": self.flops / 1e9,
            "model_gflops": self.model_flops / 1e9,
            "useful_frac": self.useful_flops_frac,
            "mfu_at_roofline": self.mfu,
            "per_device_gb": self.per_device_bytes / 1e9,
            "coll_by_op_mb": {k: v / 1e6 for k, v in self.coll_by_op.items()
                              if v},
            "raw_gflops_perdev": self.raw_flops / 1e9,
            "top_collectives": list(self.top_collectives[:6]),
        }


def from_compiled(compiled, *, arch: str, cell: str, mesh_name: str,
                  chips: int, model_flops: float) -> Roofline:
    """Build the roofline record from a compiled (partitioned) executable.

    FLOPs / bytes / collective bytes come from the scan-aware HLO analyzer
    (roofline/hlo_parse.py): ``cost_analysis()`` counts while bodies ONCE,
    ignoring the scan-over-layers trip count, so it is kept only as the raw
    reference (``raw_*``).
    """
    from repro.roofline.hlo_parse import analyze
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    tot = analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    per_device_footprint = int(
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0))
    return Roofline(
        arch=arch, cell=cell, mesh=mesh_name, chips=chips,
        flops=tot.flops * chips,
        hbm_bytes=tot.dot_bytes * chips,
        coll_bytes=tot.coll_total * chips,
        coll_by_op={k: int(v) for k, v in tot.coll_bytes.items()},
        model_flops=model_flops,
        per_device_bytes=per_device_footprint,
        raw_flops=raw_flops, raw_bytes=raw_bytes,
        top_collectives=tuple(t[1] for t in tot.top_collectives),
    )


# ---------------------------------------------------------------------------
# Kernel-level roofline (no collectives at kernel scope)
# ---------------------------------------------------------------------------

def kernel_roofline_time(flops: float, hbm_bytes: float, *,
                         chips: int = 1) -> float:
    """max(compute, memory) time for one kernel on the target hardware.

    The two-term roofline for a single-chip kernel: whichever of the MXU
    FLOP rate and the HBM stream rate binds.  Used by kernel_bench to
    report how close a measured kernel runs to the TPU v5e hardware limit.
    """
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = hbm_bytes / (chips * HBM_BW)
    return max(t_compute, t_memory)


def attained_fraction(measured_s: float, flops: float, hbm_bytes: float, *,
                      chips: int = 1) -> float:
    """roofline_time / measured_time — 1.0 means running at the roofline."""
    if measured_s <= 0:
        return 0.0
    return kernel_roofline_time(flops, hbm_bytes, chips=chips) / measured_s


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6ND) helpers
# ---------------------------------------------------------------------------

def count_active_params(cfg, params_shapes) -> Tuple[int, int]:
    """(total, active) param counts from a ShapeDtypeStruct tree.

    Active discounts MoE experts to top_k/n_experts of expert weights and
    excludes the embedding table (standard 6ND convention counts only
    FLOP-bearing matmul params; the unembed projection IS counted).
    """
    import jax
    import numpy as np
    total = 0
    active = 0
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if "embed" in keys and "pos" not in keys and not getattr(
                cfg, "tie_embeddings", False):
            # untied input embedding: a gather, not a matmul
            if keys.endswith("embed"):
                continue
        if "/moe/w" in keys or "/moe/router" in keys:
            if "/moe/w" in keys and cfg.n_experts:
                n = n * cfg.top_k // cfg.n_experts
        active += n
    return total, active


def model_flops_for_cell(cfg, cell, params_shapes) -> float:
    """6 * N_active * D for train; 2 * N_active * D for inference cells."""
    _, active = count_active_params(cfg, params_shapes)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active * tokens
    tokens = cell.global_batch * 1          # one decode token
    return 2.0 * active * tokens
