"""Scan-aware analyzer for optimized, partitioned HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
ignoring the trip count — under a scan-over-layers model (every arch here)
it undercounts FLOPs, bytes and collectives by ~n_layers x. XLA attaches
``backend_config={"known_trip_count":{"n":...}}`` to while ops lowered from
``lax.scan``, so an exact account is recoverable from the HLO text:

  * computations are parsed into instruction lists;
  * dot FLOPs = 2 x |result| x |contracting dims| (shapes resolved through a
    per-computation name->type map);
  * per-instruction byte flow for dots (lhs+rhs+out) approximates HBM
    traffic of the matmul-dominated graph (elementwise chains fuse and ride
    along; documented as an under-count for SSM decay math);
  * collective bytes per op kind (all-gather counts the gathered result,
    reduce-scatter the pre-scatter operand — the wire-dominant side);
  * fusion/call/while recurse with multiplier = trip count.

Everything is per-DEVICE (the partitioned module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_PARAM_IDX = re.compile(r"parameter\((\d+)\)")
_SOURCE = re.compile(r'source_file="([^"]+)"(?:\s+source_line=(\d+))?')


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over all array shapes in a type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    """(dtype, dims) of the FIRST array shape in a type string."""
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    types: Dict[str, str]                 # result name -> type str
    root: Optional[str] = None            # ROOT instruction name

    def index(self) -> Dict[str, Instr]:
        return {i.name: i for i in self.instrs}


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(2), [], {})
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            is_root, name, type_str, opcode = m.groups()
            cur.instrs.append(Instr(name, type_str, opcode, stripped))
            cur.types[name] = type_str
            if is_root:
                cur.root = name
        elif "=" not in stripped and stripped.startswith("%"):
            # computation parameter declaration lines (rare in this format)
            pass
    for comp in comps.values():
        if comp.root is None and comp.instrs:
            comp.root = comp.instrs[-1].name
    return comps


def find_entry(text: str, comps: Dict[str, Computation]) -> Optional[str]:
    """Name of the ENTRY computation (largest computation as fallback)."""
    for raw in text.splitlines():
        s = raw.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HDR.match(s)
            if m:
                return m.group(2)
            break
    if comps:
        return max(comps, key=lambda c: len(comps[c].instrs))
    return None


def source_location(line: str) -> Optional[Tuple[str, int]]:
    """(source_file, source_line) from an instruction's metadata, if any."""
    m = _SOURCE.search(line)
    if not m:
        return None
    return m.group(1), int(m.group(2) or 0)


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in COLLECTIVES})
    top_collectives: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)

    def add_coll(self, op: str, nbytes: float, line: str):
        self.coll_bytes[op] += nbytes
        self.top_collectives.append((nbytes, line[:180]))
        self.top_collectives.sort(key=lambda t: -t[0])
        del self.top_collectives[12:]

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


def operand_names(line: str, opcode: str) -> List[str]:
    """Operand instruction names inside the opcode's parens."""
    start = line.find(opcode + "(")
    if start < 0:
        return []
    depth = 0
    args = ""
    for ch in line[start + len(opcode):]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        if ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    return _OPERANDS.findall(args)


def _resolve_type(name: str, comp: Computation,
                  comps: Dict[str, Computation]) -> Optional[str]:
    if name in comp.types:
        return comp.types[name]
    for c in comps.values():             # params defined elsewhere: fallback
        if name in c.types:
            return c.types[name]
    return None


def analyze(text: str) -> Totals:
    comps = parse_module(text)
    entry = None
    for raw in text.splitlines():
        s = raw.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HDR.match(s)
            if m:
                entry = m.group(2)
            break
    if entry is None:                    # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs))

    totals = Totals()
    visited_stack = set()

    def visit(comp_name: str, mult: float):
        if comp_name not in comps or comp_name in visited_stack:
            return
        visited_stack.add(comp_name)
        comp = comps[comp_name]
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                res = _shape_dims(ins.type_str)
                if res is None:
                    continue
                _, rdims = res
                out_elems = 1
                for d in rdims:
                    out_elems *= d
                cdims = _LHS_CDIMS.search(ins.line)
                contract = 1
                ops = operand_names(ins.line, "dot")
                if cdims and ops:
                    lhs_t = _resolve_type(ops[0], comp, comps)
                    if lhs_t:
                        lt = _shape_dims(lhs_t)
                        if lt and cdims.group(1):
                            for d in cdims.group(1).split(","):
                                di = int(d)
                                if di < len(lt[1]):
                                    contract *= lt[1][di]
                totals.flops += mult * 2.0 * out_elems * contract
                nb = _shape_elems_bytes(ins.type_str)[1]
                for o in ops[:2]:
                    t = _resolve_type(o, comp, comps)
                    if t:
                        nb += _shape_elems_bytes(t)[1]
                totals.dot_bytes += mult * nb
            elif any(op == c or op == c + "-start" for c in COLLECTIVES):
                base = op[:-6] if op.endswith("-start") else op
                nbytes = _shape_elems_bytes(ins.type_str)[1]
                if base == "reduce-scatter":
                    onb = 0
                    for o in operand_names(ins.line, op):
                        t = _resolve_type(o, comp, comps)
                        if t:
                            onb += _shape_elems_bytes(t)[1]
                    nbytes = max(nbytes, onb)
                if base == "all-reduce":
                    # result==operand; wire moves ~2x (reduce+broadcast) but
                    # convention here counts the tensor once
                    pass
                totals.add_coll(base, mult * nbytes,
                                f"x{mult:g} {ins.line}")
            elif op == "while":
                tm = _TRIP.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                cm = _CALLS.search(ins.line)
                if cm:
                    visit(cm.group(1), mult * trip)
            elif op in ("fusion", "call", "custom-call", "conditional",
                        "reduce", "reduce-window", "scatter", "select-and-scatter",
                        "sort", "map", "all-reduce", "async-start"):
                for target in _CALLS.findall(ins.line):
                    visit(target, mult)
        visited_stack.discard(comp_name)

    visit(entry, 1.0)
    return totals


# ---------------------------------------------------------------------------
# Hot-path extraction (used by repro.vet's lowering analyzer)
# ---------------------------------------------------------------------------

DOT_OPS = ("dot", "convolution")
_CALL_LIKE = ("fusion", "call", "custom-call", "conditional", "map",
              "reduce", "reduce-window", "scatter", "select-and-scatter")


def opcode_histogram(comps: Dict[str, Computation]) -> Dict[str, int]:
    """Opcode -> count over every computation of a parsed module."""
    hist: Dict[str, int] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            hist[ins.opcode] = hist.get(ins.opcode, 0) + 1
    return dict(sorted(hist.items()))


class _Frame:
    """Binds one computation's parameters to the calling frame's operands."""

    __slots__ = ("comp", "params", "parent")

    def __init__(self, comp: Computation,
                 params: Optional[List[str]] = None,
                 parent: Optional["_Frame"] = None):
        self.comp = comp
        self.params = params
        self.parent = parent


@dataclasses.dataclass
class HotPathReport:
    """Every dot of a module plus the instructions feeding its operands.

    ``feeding`` is the union (dedup'd by (computation, name)) of the
    backward operand closures of all dots — parameter hops cross fusion
    and call boundaries, ``while`` bodies are included whole (a sound
    over-approximation).  ``histogram()`` is what the zero-overhead
    verdict consumes: how many gather/transpose/copy/... ops the matmul
    hot path actually contains in the optimized program.
    """

    dots: List[Tuple[str, Instr]]
    feeding: List[Tuple[str, Instr]]

    def histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for _, ins in self.feeding:
            hist[ins.opcode] = hist.get(ins.opcode, 0) + 1
        return dict(sorted(hist.items()))

    def feeding_of(self, *opcodes: str) -> List[Tuple[str, Instr]]:
        return [(c, i) for c, i in self.feeding if i.opcode in opcodes]


def hot_path(text: str) -> HotPathReport:
    """Backward operand closure of every dot reachable from ENTRY."""
    comps = parse_module(text)
    entry = find_entry(text, comps)
    dots: List[Tuple[str, Instr]] = []
    feeding: Dict[Tuple[str, str], Tuple[str, Instr]] = {}

    def closure(frame: _Frame, start: List[str]) -> None:
        work: List[Tuple[_Frame, str]] = [(frame, n) for n in start]
        seen = set()
        while work:
            fr, name = work.pop()
            if (fr.comp.name, name) in seen:
                continue
            seen.add((fr.comp.name, name))
            ins = fr.comp.index().get(name)
            if ins is None:
                continue
            if ins.opcode == "parameter":
                m = _PARAM_IDX.search(ins.line)
                if m and fr.params is not None and fr.parent is not None:
                    k = int(m.group(1))
                    if k < len(fr.params):
                        work.append((fr.parent, fr.params[k]))
                continue
            feeding.setdefault((fr.comp.name, name), (fr.comp.name, ins))
            for target in _CALLS.findall(ins.line) + _COND.findall(ins.line):
                if target in comps:
                    child = _Frame(comps[target],
                                   operand_names(ins.line, ins.opcode), fr)
                    if ins.opcode == "while":
                        # loop state flows through every body instruction
                        work.extend((child, i.name)
                                    for i in comps[target].instrs)
                    elif comps[target].root is not None:
                        work.append((child, comps[target].root))
            for o in operand_names(ins.line, ins.opcode):
                work.append((fr, o))

    def visit(frame: _Frame, path: Tuple[str, ...]) -> None:
        if frame.comp.name in path:
            return
        path = path + (frame.comp.name,)
        for ins in frame.comp.instrs:
            if ins.opcode in DOT_OPS:
                dots.append((frame.comp.name, ins))
                closure(frame, operand_names(ins.line, ins.opcode))
            elif ins.opcode in _CALL_LIKE or ins.opcode == "while":
                for target in (_CALLS.findall(ins.line)
                               + _COND.findall(ins.line)):
                    if target in comps:
                        visit(_Frame(comps[target],
                                     operand_names(ins.line, ins.opcode),
                                     frame), path)

    if entry is not None and entry in comps:
        visit(_Frame(comps[entry]), ())
    # the dots themselves are not "feeding" instructions
    for cname, ins in dots:
        feeding.pop((cname, ins.name), None)
    return HotPathReport(dots=dots, feeding=list(feeding.values()))
