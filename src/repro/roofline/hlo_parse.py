"""Scan-aware analyzer for optimized, partitioned HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
ignoring the trip count — under a scan-over-layers model (every arch here)
it undercounts FLOPs, bytes and collectives by ~n_layers x. XLA attaches
``backend_config={"known_trip_count":{"n":...}}`` to while ops lowered from
``lax.scan``, so an exact account is recoverable from the HLO text:

  * computations are parsed into instruction lists;
  * dot FLOPs = 2 x |result| x |contracting dims| (shapes resolved through a
    per-computation name->type map);
  * per-instruction byte flow for dots (lhs+rhs+out) approximates HBM
    traffic of the matmul-dominated graph (elementwise chains fuse and ride
    along; documented as an under-count for SSM decay math);
  * collective bytes per op kind (all-gather counts the gathered result,
    reduce-scatter the pre-scatter operand — the wire-dominant side);
  * fusion/call/while recurse with multiplier = trip count.

Everything is per-DEVICE (the partitioned module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over all array shapes in a type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    """(dtype, dims) of the FIRST array shape in a type string."""
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    types: Dict[str, str]                 # result name -> type str


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(2), [], {})
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, type_str, opcode = m.groups()
            cur.instrs.append(Instr(name, type_str, opcode, stripped))
            cur.types[name] = type_str
        elif "=" not in stripped and stripped.startswith("%"):
            # computation parameter declaration lines (rare in this format)
            pass
    return comps


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in COLLECTIVES})
    top_collectives: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)

    def add_coll(self, op: str, nbytes: float, line: str):
        self.coll_bytes[op] += nbytes
        self.top_collectives.append((nbytes, line[:180]))
        self.top_collectives.sort(key=lambda t: -t[0])
        del self.top_collectives[12:]

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


def _operand_names(line: str, opcode: str) -> List[str]:
    """Operand instruction names inside the opcode's parens."""
    start = line.find(opcode + "(")
    if start < 0:
        return []
    depth = 0
    args = ""
    for ch in line[start + len(opcode):]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        if ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    return _OPERANDS.findall(args)


def _resolve_type(name: str, comp: Computation,
                  comps: Dict[str, Computation]) -> Optional[str]:
    if name in comp.types:
        return comp.types[name]
    for c in comps.values():             # params defined elsewhere: fallback
        if name in c.types:
            return c.types[name]
    return None


def analyze(text: str) -> Totals:
    comps = parse_module(text)
    entry = None
    for raw in text.splitlines():
        s = raw.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HDR.match(s)
            if m:
                entry = m.group(2)
            break
    if entry is None:                    # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs))

    totals = Totals()
    visited_stack = set()

    def visit(comp_name: str, mult: float):
        if comp_name not in comps or comp_name in visited_stack:
            return
        visited_stack.add(comp_name)
        comp = comps[comp_name]
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                res = _shape_dims(ins.type_str)
                if res is None:
                    continue
                _, rdims = res
                out_elems = 1
                for d in rdims:
                    out_elems *= d
                cdims = _LHS_CDIMS.search(ins.line)
                contract = 1
                ops = _operand_names(ins.line, "dot")
                if cdims and ops:
                    lhs_t = _resolve_type(ops[0], comp, comps)
                    if lhs_t:
                        lt = _shape_dims(lhs_t)
                        if lt and cdims.group(1):
                            for d in cdims.group(1).split(","):
                                di = int(d)
                                if di < len(lt[1]):
                                    contract *= lt[1][di]
                totals.flops += mult * 2.0 * out_elems * contract
                nb = _shape_elems_bytes(ins.type_str)[1]
                for o in ops[:2]:
                    t = _resolve_type(o, comp, comps)
                    if t:
                        nb += _shape_elems_bytes(t)[1]
                totals.dot_bytes += mult * nb
            elif any(op == c or op == c + "-start" for c in COLLECTIVES):
                base = op[:-6] if op.endswith("-start") else op
                nbytes = _shape_elems_bytes(ins.type_str)[1]
                if base == "reduce-scatter":
                    onb = 0
                    for o in _operand_names(ins.line, op):
                        t = _resolve_type(o, comp, comps)
                        if t:
                            onb += _shape_elems_bytes(t)[1]
                    nbytes = max(nbytes, onb)
                if base == "all-reduce":
                    # result==operand; wire moves ~2x (reduce+broadcast) but
                    # convention here counts the tensor once
                    pass
                totals.add_coll(base, mult * nbytes,
                                f"x{mult:g} {ins.line}")
            elif op == "while":
                tm = _TRIP.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                cm = _CALLS.search(ins.line)
                if cm:
                    visit(cm.group(1), mult * trip)
            elif op in ("fusion", "call", "custom-call", "conditional",
                        "reduce", "reduce-window", "scatter", "select-and-scatter",
                        "sort", "map", "all-reduce", "async-start"):
                for target in _CALLS.findall(ins.line):
                    visit(target, mult)
        visited_stack.discard(comp_name)

    visit(entry, 1.0)
    return totals
