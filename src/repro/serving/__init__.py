from repro.serving import cache
from repro.serving.engine import decode_step, generate, prefill
from repro.serving.lm_driver import GenerateDriver
from repro.serving.metrics import GroupMetrics, LatencyWindow, MetricsRegistry
from repro.serving.scheduler import BatchPolicy, BatchScheduler, QueueFullError
from repro.serving.stencil_driver import StencilDriver

__all__ = [
    "BatchPolicy", "BatchScheduler", "GenerateDriver", "GroupMetrics",
    "LatencyWindow", "MetricsRegistry", "QueueFullError", "StencilDriver",
    "cache", "decode_step", "generate", "prefill",
]
