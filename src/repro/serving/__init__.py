from repro.serving import cache
from repro.serving.engine import decode_step, generate, prefill

__all__ = ["cache", "decode_step", "generate", "prefill"]
