"""Decode-state (KV / SSM) caches for all six families.

Caches are RING buffers of length ``ring``:
  full attention  -> ring = cache_len (the cell's seq_len)
  sliding window  -> ring = min(window, cache_len)  (bounds long_500k)
  SSM             -> O(1) state, no ring at all
Slot for position p is ``p % ring``; ``kv_pos`` (ring,) records which absolute
position occupies each slot (-1 = empty) and drives the attention mask, so
window/causal semantics survive wrap-around. Batched decoding is
position-aligned (one scalar ``pos`` per cache), the standard batched-serving
regime.

All init_* functions are jnp-pure and run under jax.eval_shape for the
dry-run (decode cells lower serve_step against these ShapeDtypeStructs).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Cache = Dict[str, Any]


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def ring_len(cfg: ModelConfig, cache_len: int) -> int:
    w = cfg.decode_window or cfg.sliding_window
    return min(w, cache_len) if w else cache_len


def _kv(cfg: ModelConfig, n: int, batch: int, ring: int):
    shape = (n, batch, ring, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, _dt(cfg)), "v": jnp.zeros(shape, _dt(cfg))}


def _ssm_states(cfg: ModelConfig, n: int, batch: int):
    h, hd, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ch = cfg.d_inner + 2 * ns
    return {
        "ssm": jnp.zeros((n, batch, h, hd, ns), jnp.float32),
        "conv": jnp.zeros((n, batch, cfg.conv_width - 1, ch), _dt(cfg)),
    }


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Cache:
    ring = ring_len(cfg, cache_len)
    base = {"pos": jnp.zeros((), jnp.int32),
            "kv_pos": jnp.full((ring,), -1, jnp.int32)}
    fam = cfg.family
    if fam in ("dense", "moe"):
        return {**base, **_kv(cfg, cfg.n_layers, batch, ring)}
    if fam == "ssm":
        return {"pos": base["pos"], **_ssm_states(cfg, cfg.n_layers, batch)}
    if fam == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        return {**base,
                **_ssm_states(cfg, cfg.n_layers, batch),
                "shared": _kv(cfg, ng, batch, ring)}
    if fam == "vlm":
        ng = cfg.n_layers // cfg.cross_attn_every
        return {**base,
                **_kv(cfg, cfg.n_layers, batch, ring),
                "cross": _kv(cfg, ng, batch, cfg.n_img_tokens)}
    if fam == "encdec":
        return {**base,
                **_kv(cfg, cfg.n_layers, batch, ring),
                "cross": _kv(cfg, cfg.n_layers, batch, cfg.n_frames)}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# prefill -> cache construction
# ---------------------------------------------------------------------------

def ring_pack(k_full: jnp.ndarray, ring: int) -> jnp.ndarray:
    """(N, B, S, ...) full-sequence K/V -> (N, B, ring, ...) ring buffer.

    Keeps the last ``ring`` positions, each at slot p % ring.
    """
    s = k_full.shape[2]
    if s <= ring:
        pad = [(0, 0)] * k_full.ndim
        pad[2] = (0, ring - s)
        return jnp.pad(k_full, pad)
    last = k_full[:, :, s - ring:]
    return jnp.roll(last, (s - ring) % ring, axis=2)


def ring_positions(s: int, ring: int) -> jnp.ndarray:
    """kv_pos (ring,) after prefilling positions [0, s)."""
    if s <= ring:
        slots = jnp.arange(ring, dtype=jnp.int32)
        return jnp.where(slots < s, slots, -1)
    pos = jnp.arange(s - ring, s, dtype=jnp.int32)
    return jnp.roll(pos, (s - ring) % ring)


def write_token(kc: jnp.ndarray, k_new: jnp.ndarray, slot) -> jnp.ndarray:
    """Insert one token's K/V at ``slot``. kc (B, ring, ...); k_new (B, 1, ...)."""
    return jax.lax.dynamic_update_slice_in_dim(kc, k_new.astype(kc.dtype),
                                               slot, axis=1)
