"""Serving: prefill + single-token decode steps for all families.

``decode_step`` is THE graph lowered for the ``decode_32k`` / ``long_500k``
dry-run cells: one new token against a ring KV cache (or O(1) SSM state).
Layer loops are ``lax.scan`` over stacked params+caches, so the compiled
artifact is depth-independent.

Batched decoding is position-aligned (scalar ``pos``); the continuous-
batching driver (`serving/lm_driver.py`, on the shared
`serving/scheduler.py` layer — same machinery as the stencil driver in
`serving/stencil_driver.py`) packs requests into these aligned batches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import model as M
from repro.serving import cache as C


# ---------------------------------------------------------------------------
# shared decode sub-blocks
# ---------------------------------------------------------------------------

def _embed_one(p, cfg, token, pos):
    x = jnp.take(p["embed"], token, axis=0).astype(M._dt(cfg))   # (B,1,D)
    if cfg.pos_emb == "learned":
        pe = jax.lax.dynamic_slice_in_dim(p["pos"], jnp.minimum(
            pos, cfg.max_seq - 1), 1, axis=0)
        x = x + pe[None].astype(x.dtype)
    return x


def _attn_decode(pl, x, cfg, kc, vc, pos, kv_pos, slot, *, rope=True):
    """One-token self-attention against a ring cache. Returns (y, kc, vc)."""
    b = x.shape[0]
    h = L.apply_norm(pl["attn_norm"], x, cfg)
    qp = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q, k, v = L._qkv(pl["attn"], h, h, cfg, qp, qp, rope)
    kc = C.write_token(kc, k, slot)
    vc = C.write_token(vc, v, slot)
    kvp = jnp.broadcast_to(kv_pos[None], (b, kv_pos.shape[0]))
    o = L.decode_attention(q, kc, vc, qp, kvp, window=cfg.sliding_window)
    y = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(o.dtype))
    return x + y, kc, vc


def _ffn_decode(pl, x, cfg):
    h = L.apply_norm(pl["mlp_norm"], x, cfg)
    if "moe" in pl:
        y, _ = L.apply_moe(pl["moe"], h, cfg)
    else:
        y = L.apply_mlp(pl["mlp"], h, cfg)
    return x + y


def _cross_decode(pl, x, cfg, kc, vc, mem_pos):
    """One-token cross-attention over a static memory cache."""
    b = x.shape[0]
    h = L.apply_norm(pl["attn_norm"], x, cfg)
    qp = jnp.zeros((b, 1), jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", h, pl["attn"]["wq"].astype(h.dtype))
    if cfg.qk_norm:
        q = L.rms_head_norm(pl["attn"]["q_norm"], q, cfg.norm_eps)
    kvp = jnp.broadcast_to(mem_pos[None], (b, mem_pos.shape[0]))
    o = L.decode_attention(q, kc, vc, qp, kvp, window=None, causal=False)
    y = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(o.dtype))
    if "gate" in pl["attn"]:
        y = jnp.tanh(pl["attn"]["gate"].astype(y.dtype)) * y
    x = x + y
    if "mlp" in pl:
        h = L.apply_norm(pl["mlp_norm"], x, cfg)
        x = x + L.apply_mlp(pl["mlp"], h, cfg)
    return x


def _mamba_decode(pl, x, st, cfg):
    h = L.apply_norm(pl["norm"], x, cfg)
    y, st = S.apply_mamba_decode(pl["mamba"], h, st, cfg)
    return x + y, st


# ---------------------------------------------------------------------------
# decode step (per family, unified entry)
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, cache: Dict[str, Any],
                token: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """token (B, 1) int32 -> (logits (B, 1, V) fp32, new cache)."""
    pos = cache["pos"]
    x = _embed_one(params, cfg, token, pos)
    x = constrain(x, ("batch", None, "embed"))
    fam = cfg.family
    new = dict(cache)

    has_ring = "kv_pos" in cache
    if has_ring:
        ring = cache["kv_pos"].shape[0]
        slot = jax.lax.rem(pos, ring)
        kv_pos = jax.lax.dynamic_update_slice(
            cache["kv_pos"], pos[None], (slot,))
        new["kv_pos"] = kv_pos

    if fam in ("dense", "moe"):
        def body(x, layer):
            pl, kc, vc = layer
            x, kc, vc = _attn_decode(pl, x, cfg, kc, vc, pos, kv_pos, slot)
            x = _ffn_decode(pl, x, cfg)
            return constrain(x, ("batch", None, "embed")), (kc, vc)
        x, (k2, v2) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new["k"], new["v"] = k2, v2

    elif fam == "ssm":
        def body(x, layer):
            pl, st = layer
            x, st = _mamba_decode(pl, x, st, cfg)
            return x, st
        x, st2 = jax.lax.scan(
            body, x, (params["layers"],
                      {"ssm": cache["ssm"], "conv": cache["conv"]}))
        new["ssm"], new["conv"] = st2["ssm"], st2["conv"]

    elif fam == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        st_in = {"ssm": cache["ssm"].reshape((ng, cfg.attn_every) +
                                             cache["ssm"].shape[1:]),
                 "conv": cache["conv"].reshape((ng, cfg.attn_every) +
                                               cache["conv"].shape[1:])}
        shared = params["shared"]

        def group(x, layer):
            gp, st, kc, vc = layer

            def inner(x, li):
                pl, sti = li
                return _mamba_decode(pl, x, sti, cfg)
            x, st2 = jax.lax.scan(inner, x, (gp, st))
            x, kc, vc = _attn_decode(shared, x, cfg, kc, vc, pos, kv_pos,
                                     slot)
            x = _ffn_decode(shared, x, cfg)
            return x, (st2, kc, vc)
        x, (st2, k2, v2) = jax.lax.scan(
            group, x, (params["groups"], st_in,
                       cache["shared"]["k"], cache["shared"]["v"]))
        new["ssm"] = st2["ssm"].reshape((-1,) + st2["ssm"].shape[2:])
        new["conv"] = st2["conv"].reshape((-1,) + st2["conv"].shape[2:])
        new["shared"] = {"k": k2, "v": v2}

    elif fam == "vlm":
        ng = cfg.n_layers // cfg.cross_attn_every
        mem_pos = jnp.arange(cfg.n_img_tokens, dtype=jnp.int32)
        kr = cache["k"].reshape((ng, cfg.cross_attn_every) +
                                cache["k"].shape[1:])
        vr = cache["v"].reshape((ng, cfg.cross_attn_every) +
                                cache["v"].shape[1:])

        def group(x, layer):
            cp, sp, ck, cv, kc, vc = layer
            x = _cross_decode(cp, x, cfg, ck, cv, mem_pos)

            def inner(x, li):
                pl, kci, vci = li
                x, kci, vci = _attn_decode(pl, x, cfg, kci, vci, pos,
                                           kv_pos, slot)
                x = _ffn_decode(pl, x, cfg)
                return x, (kci, vci)
            x, (kc, vc) = jax.lax.scan(inner, x, (sp, kc, vc))
            return x, (kc, vc)
        x, (k2, v2) = jax.lax.scan(
            group, x, (params["cross"], params["groups"],
                       cache["cross"]["k"], cache["cross"]["v"], kr, vr))
        new["k"] = k2.reshape((-1,) + k2.shape[2:])
        new["v"] = v2.reshape((-1,) + v2.shape[2:])

    elif fam == "encdec":
        mem_pos = jnp.arange(cfg.n_frames, dtype=jnp.int32)

        def body(x, layer):
            pl, kc, vc, ck, cv = layer
            x, kc, vc = _attn_decode(pl, x, cfg, kc, vc, pos, kv_pos, slot,
                                     rope=False)
            h = L.apply_norm(pl["cross_norm"], x, cfg)
            qp = jnp.zeros((x.shape[0], 1), jnp.int32)
            q = jnp.einsum("bsd,dhk->bshk", h,
                           pl["cross"]["wq"].astype(h.dtype))
            kvp = jnp.broadcast_to(mem_pos[None], (x.shape[0],
                                                   cfg.n_frames))
            o = L.decode_attention(q, ck, cv, qp, kvp, window=None,
                                   causal=False)
            x = x + jnp.einsum("bshk,hkd->bsd", o,
                               pl["cross"]["wo"].astype(o.dtype))
            x = _ffn_decode(pl, x, cfg)
            return x, (kc, vc)
        x, (k2, v2) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["cross"]["k"], cache["cross"]["v"]))
        new["k"], new["v"] = k2, v2
    else:
        raise ValueError(fam)

    logits = M.unembed(params, cfg, x)
    new["pos"] = pos + 1
    return logits, new


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _cross_kv(attn_p, mem, cfg):
    """Precompute cross-attention K/V over a memory. attn_p leaves may carry a
    leading stack axis (G or L)."""
    def one(pl):
        k = jnp.einsum("btd,dhk->bthk", mem, pl["wk"].astype(mem.dtype))
        v = jnp.einsum("btd,dhk->bthk", mem, pl["wv"].astype(mem.dtype))
        if cfg.qk_norm:
            k = L.rms_head_norm(pl["k_norm"], k, cfg.norm_eps)
        return {"k": k, "v": v}
    return jax.vmap(one)(attn_p)


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray, cache_len: int,
            memory: Optional[jnp.ndarray] = None):
    """tokens (B, S) -> (logits (B, S, V), cache ready for decode at pos=S)."""
    b, s = tokens.shape
    logits, _, kv = M.forward(params, cfg, tokens, memory=memory,
                              collect_kv=True)
    ring = C.ring_len(cfg, cache_len)
    cc = C.init_cache(cfg, b, cache_len)
    cc["pos"] = jnp.asarray(s, jnp.int32)
    if "kv_pos" in cc:
        cc["kv_pos"] = C.ring_positions(s, ring)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        k, v = kv["self"]
        cc["k"] = C.ring_pack(k.astype(cc["k"].dtype), ring)
        cc["v"] = C.ring_pack(v.astype(cc["v"].dtype), ring)
    if fam in ("ssm", "hybrid"):
        cc["ssm"] = kv["states"]["ssm"]
        cc["conv"] = kv["states"]["conv"].astype(cc["conv"].dtype)
    if fam == "hybrid":
        k, v = kv["shared"]
        cc["shared"] = {"k": C.ring_pack(k.astype(M._dt(cfg)), ring),
                        "v": C.ring_pack(v.astype(M._dt(cfg)), ring)}
    if fam == "vlm":
        mem = memory.astype(M._dt(cfg))
        cc["cross"] = _cross_kv(params["cross"]["attn"], mem, cfg)
    if fam == "encdec":
        mem = kv["memory"]
        cc["cross"] = _cross_kv(params["dec_layers"]["cross"], mem, cfg)
    return logits, cc


def generate(params, cfg: ModelConfig, prompt: jnp.ndarray, n_new: int,
             cache_len: int, memory: Optional[jnp.ndarray] = None,
             greedy: bool = True, key: Optional[jax.Array] = None):
    """Autoregressive generation: prefill + n_new greedy/sampled steps."""
    logits, cc = prefill(params, cfg, prompt, cache_len, memory=memory)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    def step(carry, _):
        tok, cc, k = carry
        lg, cc = decode_step(params, cfg, cc, tok)
        if greedy:
            nxt = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        else:
            k, sub = jax.random.split(k)
            nxt = jax.random.categorical(sub, lg[:, -1])[:, None]
        return (nxt, cc, k), nxt[:, 0]

    key = key if key is not None else jax.random.PRNGKey(0)
    (_, cc, _), toks = jax.lax.scan(step, (tok, cc, key), None, length=n_new)
    return jnp.concatenate([tok, toks.T[:, :-1]], axis=1), cc
