"""LM generate driver on the shared continuous-batching scheduler.

Decode batches must be *position-aligned* (scalar ``pos`` against ring
KV caches — see `serving/engine.py`), so the batchable unit is
``(prompt_len, n_new, memory signature)``: requests with the same
signature stack into one ``generate`` call (prefill + scanned decode)
and stream back per-request token arrays.

This is the LM half of the one-scheduling-layer refactor: it reuses the
exact :class:`~repro.serving.scheduler.BatchScheduler` +
:class:`~repro.serving.metrics.MetricsRegistry` machinery the stencil
driver (`serving/stencil_driver.py`) runs on, so occupancy/latency/
backpressure semantics — and their metrics — are identical across both
traffic classes.

    driver = GenerateDriver(params, cfg, cache_len=64)
    fut = driver.submit(prompt_tokens, n_new=16)      # (S,) int32
    toks = fut.result()                               # (n_new,) int32
"""
from __future__ import annotations

import time
from concurrent.futures import Future
from typing import List

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.serving import engine as E
from repro.serving.metrics import MetricsRegistry, merged_latency
from repro.serving.scheduler import BatchPolicy, BatchScheduler, QueueFullError


class _GenJob:
    __slots__ = ("prompt", "memory", "t_submit")

    def __init__(self, prompt, memory):
        self.prompt = prompt
        self.memory = memory
        self.t_submit = time.monotonic()


class GenerateDriver:
    """Packs single-prompt generate requests into aligned batches."""

    def __init__(self, params, cfg: ModelConfig, *,
                 cache_len: int | None = None,
                 policy: BatchPolicy | None = None,
                 greedy: bool = True,
                 autostart: bool = True):
        self.params = params
        self.cfg = cfg
        self.cache_len = cache_len
        self.greedy = greedy
        self.metrics_registry = MetricsRegistry()
        self._sched = BatchScheduler(self._run_batch, policy,
                                     name=f"lm-{cfg.name}",
                                     autostart=autostart)

    # -- admission -----------------------------------------------------------
    def group_key(self, prompt, n_new: int, memory=None) -> str:
        mem = ("none" if memory is None
               else "x".join(str(s) for s in memory.shape))
        return f"len={prompt.shape[0]};new={n_new};mem={mem}"

    def submit(self, prompt, n_new: int, memory=None) -> Future:
        """Enqueue one request. ``prompt`` is (S,) int32; result (n_new,)."""
        prompt = jnp.asarray(prompt)
        if prompt.ndim != 1:
            raise ValueError(
                f"prompt must be a 1-D token array, got {tuple(prompt.shape)}")
        needs_mem = self.cfg.family in ("vlm", "encdec")
        if needs_mem and memory is None:
            raise ValueError(f"family {self.cfg.family!r} requires a memory")
        key = (self.group_key(prompt, n_new, memory), n_new)
        m = self.metrics_registry.group(key[0])
        try:
            fut = self._sched.submit(key, _GenJob(prompt, memory))
        except QueueFullError:
            m.bump(rejected=1)
            raise
        m.bump(submitted=1)
        return fut

    # -- lifecycle / introspection -------------------------------------------
    def start(self) -> "GenerateDriver":
        self._sched.start()
        return self

    def drain(self) -> None:
        self._sched.drain()

    def close(self, wait: bool = True) -> None:
        self._sched.shutdown(wait=wait)

    def __enter__(self) -> "GenerateDriver":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(wait=True)

    def queue_depth(self) -> int:
        return self._sched.queue_depth()

    def metrics(self) -> dict:
        groups = [self.metrics_registry.group(k)
                  for k in self.metrics_registry.keys()]
        overall = self.metrics_registry.totals()
        overall["latency"] = merged_latency(groups).as_dict()
        overall["queue_depth"] = self._sched.queue_depth()
        return {
            "arch": self.cfg.name,
            "policy": {
                "max_batch": self._sched.policy.max_batch,
                "max_wait_ms": self._sched.policy.max_wait_ms,
                "max_queue": self._sched.policy.max_queue,
                "overflow": self._sched.policy.overflow,
            },
            "overall": overall,
            "groups": self.metrics_registry.as_dict(),
        }

    # -- execution -----------------------------------------------------------
    def _run_batch(self, key, jobs: List[_GenJob]) -> list:
        group_key, n_new = key
        m = self.metrics_registry.group(group_key)
        prompt_len = jobs[0].prompt.shape[0]
        cache_len = self.cache_len or (prompt_len + n_new)
        try:
            prompts = jnp.stack([j.prompt for j in jobs]).astype(jnp.int32)
            memory = (jnp.stack([j.memory for j in jobs])
                      if jobs[0].memory is not None else None)
            toks, _ = E.generate(self.params, self.cfg, prompts, n_new,
                                 cache_len, memory=memory,
                                 greedy=self.greedy)
        except BaseException:
            m.bump(failed=len(jobs))
            raise
        toks.block_until_ready()
        now = time.monotonic()
        m.bump(batches=1, batched_jobs=len(jobs), completed=len(jobs),
               payload_elems=len(jobs) * (prompt_len + n_new),
               padded_elems=len(jobs) * (prompt_len + n_new))
        for j in jobs:
            m.observe_latency(now - j.t_submit)
        return [toks[i] for i in range(len(jobs))]
