"""Serving metrics: admission counters + latency quantiles per plan group.

Every driver that schedules traffic through ``serving.scheduler`` keeps
one :class:`GroupMetrics` per batch group (for stencils: one per tuner
plan key; for LM decode: one per aligned-batch signature).  The driver
surfaces them through ``driver.metrics()`` alongside the tuner's
``PlanCache.stats`` so a fleet operator can see, per plan: queue depth,
batch occupancy, padding efficiency, p50/p99 latency, and reject counts.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, Iterable, Optional


class LatencyWindow:
    """Bounded sample window with percentile readout (seconds in, ms out)."""

    def __init__(self, maxlen: int = 4096):
        self._samples = collections.deque(maxlen=maxlen)

    def observe(self, seconds: float) -> None:
        self._samples.append(float(seconds))

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float:
        """The q-th percentile (0 < q <= 100) of the window, in seconds."""
        if not self._samples:
            return 0.0
        ordered = sorted(self._samples)
        idx = max(0, min(len(ordered) - 1,
                         int(-(-q * len(ordered) // 100)) - 1))
        return ordered[idx]

    def as_dict(self) -> dict:
        n = len(self._samples)
        return {
            "count": n,
            "p50_ms": round(self.percentile(50) * 1e3, 3),
            "p99_ms": round(self.percentile(99) * 1e3, 3),
            "mean_ms": round(sum(self._samples) / n * 1e3, 3) if n else 0.0,
            "max_ms": round(max(self._samples) * 1e3, 3) if n else 0.0,
        }


@dataclasses.dataclass
class GroupMetrics:
    """Admission + execution counters for one batch group."""

    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0
    batches: int = 0
    batched_jobs: int = 0
    payload_elems: int = 0        # useful elements actually requested
    padded_elems: int = 0         # elements executed after padding
    latency: LatencyWindow = dataclasses.field(default_factory=LatencyWindow)

    @property
    def occupancy(self) -> float:
        """Mean jobs per executed super-batch (the continuous-batching win)."""
        return self.batched_jobs / self.batches if self.batches else 0.0

    @property
    def padding_efficiency(self) -> float:
        """Fraction of executed elements that were real payload (1.0 = none wasted)."""
        return (self.payload_elems / self.padded_elems
                if self.padded_elems else 1.0)

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "rejected": self.rejected,
            "batches": self.batches,
            "batch_occupancy": round(self.occupancy, 3),
            "padding_efficiency": round(self.padding_efficiency, 4),
            "latency": self.latency.as_dict(),
        }


class MetricsRegistry:
    """Thread-safe map of group key -> GroupMetrics."""

    def __init__(self):
        self._lock = threading.Lock()
        self._groups: Dict[str, GroupMetrics] = {}

    def group(self, key: str) -> GroupMetrics:
        with self._lock:
            m = self._groups.get(key)
            if m is None:
                m = self._groups[key] = GroupMetrics()
            return m

    def keys(self) -> Iterable[str]:
        with self._lock:
            return list(self._groups)

    def totals(self) -> dict:
        """Aggregates across every group (occupancy over all batches)."""
        with self._lock:
            groups = list(self._groups.values())
        batches = sum(g.batches for g in groups)
        jobs = sum(g.batched_jobs for g in groups)
        return {
            "groups": len(groups),
            "submitted": sum(g.submitted for g in groups),
            "completed": sum(g.completed for g in groups),
            "failed": sum(g.failed for g in groups),
            "rejected": sum(g.rejected for g in groups),
            "batches": batches,
            "batch_occupancy": round(jobs / batches, 3) if batches else 0.0,
        }

    def as_dict(self, queue_depth=None) -> dict:
        """Full per-group dump; ``queue_depth`` maps key -> current depth."""
        out = {}
        with self._lock:
            items = list(self._groups.items())
        for key, m in items:
            d = m.as_dict()
            if queue_depth is not None:
                d["queue_depth"] = queue_depth(key)
            out[key] = d
        return out


def merged_latency(groups: Iterable[GroupMetrics],
                   maxlen: Optional[int] = None) -> LatencyWindow:
    """One window holding every group's samples (for fleet-level p50/p99)."""
    merged = LatencyWindow(maxlen=maxlen or 1 << 20)
    for g in groups:
        for s in g.latency._samples:
            merged.observe(s)
    return merged
