"""Serving metrics: admission counters + latency quantiles per plan group.

Every driver that schedules traffic through ``serving.scheduler`` keeps
one :class:`GroupMetrics` per batch group (for stencils: one per tuner
plan key; for LM decode: one per aligned-batch signature).  The driver
surfaces them through ``driver.metrics()`` alongside the tuner's
``PlanCache.stats`` so a fleet operator can see, per plan: queue depth,
batch occupancy, padding efficiency, p50/p99 latency, and reject counts.

Thread-safety: counters are bumped from *caller* threads (``submit``)
and the scheduler's batch thread (``_run_batch``) concurrently, and read
by whichever thread calls ``driver.metrics()``.  A bare ``m.submitted +=
1`` is a LOAD/ADD/STORE triple that interleaves under the GIL, and
sorting a deque while another thread appends raises ``RuntimeError:
deque mutated during iteration``.  So every mutation goes through
:meth:`GroupMetrics.bump` / :meth:`GroupMetrics.observe_latency` and
every read path snapshots under the same per-group lock.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, Iterable, List, Optional


class LatencyWindow:
    """Bounded sample window with percentile readout (seconds in, ms out).

    Appends and reads are internally locked: ``observe`` runs on the
    batch thread while ``percentile``/``as_dict`` run on whatever thread
    asked for metrics.
    """

    def __init__(self, maxlen: int = 4096):
        self._lock = threading.Lock()
        self._samples = collections.deque(maxlen=maxlen)

    def observe(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def samples(self) -> List[float]:
        """A point-in-time copy of the window."""
        with self._lock:
            return list(self._samples)

    def percentile(self, q: float) -> float:
        """The q-th percentile (0 < q <= 100) of the window, in seconds."""
        ordered = sorted(self.samples())
        if not ordered:
            return 0.0
        idx = max(0, min(len(ordered) - 1,
                         int(-(-q * len(ordered) // 100)) - 1))
        return ordered[idx]

    def as_dict(self) -> dict:
        snap = self.samples()
        n = len(snap)
        ordered = sorted(snap)

        def pct(q: float) -> float:
            if not ordered:
                return 0.0
            idx = max(0, min(n - 1, int(-(-q * n // 100)) - 1))
            return ordered[idx]

        return {
            "count": n,
            "p50_ms": round(pct(50) * 1e3, 3),
            "p99_ms": round(pct(99) * 1e3, 3),
            "mean_ms": round(sum(snap) / n * 1e3, 3) if n else 0.0,
            "max_ms": round(max(snap) * 1e3, 3) if n else 0.0,
        }


@dataclasses.dataclass
class GroupMetrics:
    """Admission + execution counters for one batch group.

    Mutate only through :meth:`bump` / :meth:`observe_latency`; read
    snapshots through :meth:`as_dict` (or single fields, which are
    atomic enough for display but not for read-modify-write).
    """

    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0
    batches: int = 0
    batched_jobs: int = 0
    payload_elems: int = 0        # useful elements actually requested
    padded_elems: int = 0         # elements executed after padding
    latency: LatencyWindow = dataclasses.field(default_factory=LatencyWindow)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def bump(self, **counters: int) -> None:
        """Atomically add to named counters: ``m.bump(submitted=1)``."""
        with self._lock:
            for name, delta in counters.items():
                setattr(self, name, getattr(self, name) + int(delta))

    def observe_latency(self, seconds: float) -> None:
        self.latency.observe(seconds)

    @property
    def occupancy(self) -> float:
        """Mean jobs per executed super-batch (the continuous-batching win)."""
        return self.batched_jobs / self.batches if self.batches else 0.0

    @property
    def padding_efficiency(self) -> float:
        """Fraction of executed elements that were real payload (1.0 = none wasted)."""
        return (self.payload_elems / self.padded_elems
                if self.padded_elems else 1.0)

    def as_dict(self) -> dict:
        with self._lock:
            submitted, completed = self.submitted, self.completed
            failed, rejected = self.failed, self.rejected
            batches, batched_jobs = self.batches, self.batched_jobs
            payload, padded = self.payload_elems, self.padded_elems
        return {
            "submitted": submitted,
            "completed": completed,
            "failed": failed,
            "rejected": rejected,
            "batches": batches,
            "batch_occupancy": round(batched_jobs / batches, 3)
                               if batches else 0.0,
            "padding_efficiency": round(payload / padded, 4)
                                  if padded else 1.0,
            "latency": self.latency.as_dict(),
        }


class MetricsRegistry:
    """Thread-safe map of group key -> GroupMetrics."""

    def __init__(self):
        self._lock = threading.Lock()
        self._groups: Dict[str, GroupMetrics] = {}

    def group(self, key: str) -> GroupMetrics:
        with self._lock:
            m = self._groups.get(key)
            if m is None:
                m = self._groups[key] = GroupMetrics()
            return m

    def keys(self) -> Iterable[str]:
        with self._lock:
            return list(self._groups)

    def totals(self) -> dict:
        """Aggregates across every group (occupancy over all batches)."""
        with self._lock:
            groups = list(self._groups.values())
        snaps = [g.as_dict() for g in groups]
        batches = sum(s["batches"] for s in snaps)
        jobs = sum(g.batched_jobs for g in groups)
        return {
            "groups": len(snaps),
            "submitted": sum(s["submitted"] for s in snaps),
            "completed": sum(s["completed"] for s in snaps),
            "failed": sum(s["failed"] for s in snaps),
            "rejected": sum(s["rejected"] for s in snaps),
            "batches": batches,
            "batch_occupancy": round(jobs / batches, 3) if batches else 0.0,
        }

    def as_dict(self, queue_depth=None) -> dict:
        """Full per-group dump; ``queue_depth`` maps key -> current depth."""
        out = {}
        with self._lock:
            items = list(self._groups.items())
        for key, m in items:
            d = m.as_dict()
            if queue_depth is not None:
                d["queue_depth"] = queue_depth(key)
            out[key] = d
        return out


def merged_latency(groups: Iterable[GroupMetrics],
                   maxlen: Optional[int] = None) -> LatencyWindow:
    """One window holding every group's samples (for fleet-level p50/p99)."""
    merged = LatencyWindow(maxlen=maxlen or 1 << 20)
    for g in groups:
        for s in g.latency.samples():
            merged.observe(s)
    return merged
