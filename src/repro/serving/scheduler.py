"""Continuous-batching scheduler — ONE scheduling layer for all traffic.

The serving problem is the same for stencil grids and LM decode: many
callers each submit one small job; the device wants few large aligned
batches.  ``BatchScheduler`` is the traffic-class-agnostic core both
drivers (`serving/stencil_driver.py`, `serving/lm_driver.py`) share:

  * ``submit(key, payload) -> Future`` — jobs enter a bounded queue and
    are grouped by ``key`` (whatever makes payloads batchable together:
    a tuner plan key, an aligned decode signature, ...).
  * A worker thread flushes a group when it reaches ``max_batch`` jobs
    or its oldest job has waited ``max_wait_ms`` — the classic
    continuous-batching tradeoff (text-generation-inference idiom).
  * The driver-supplied ``run_batch(key, payloads)`` callback executes
    one super-batch and returns per-job results, which are streamed
    back to callers through their futures.
  * Backpressure: at ``max_queue`` queued jobs, ``submit`` either
    blocks until space frees up or rejects with :class:`QueueFullError`
    (``overflow="block" | "reject"``).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional, Tuple

OVERFLOW_POLICIES = ("block", "reject")


class QueueFullError(RuntimeError):
    """Raised by ``submit`` when the queue is full and overflow='reject'."""


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Knobs for the batch/latency/backpressure tradeoff."""

    max_batch: int = 32           # flush a group at this many jobs
    max_wait_ms: float = 2.0      # ... or when its oldest job is this stale
    max_queue: int = 1024         # bounded admission queue (all groups)
    overflow: str = "block"       # "block" | "reject" when the queue is full

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.overflow not in OVERFLOW_POLICIES:
            raise ValueError(
                f"overflow must be one of {OVERFLOW_POLICIES}, "
                f"got {self.overflow!r}")


class _Job:
    __slots__ = ("key", "payload", "future", "t_submit")

    def __init__(self, key, payload):
        self.key = key
        self.payload = payload
        self.future: Future = Future()
        self.t_submit = time.monotonic()


class BatchScheduler:
    """Groups jobs by key and executes them as super-batches on a worker.

    ``run_batch(key, payloads)`` must return one result per payload, in
    order.  If it raises, every job in the batch gets the exception on
    its future (one bad batch never wedges the scheduler).

    With ``autostart=False`` nothing executes until :meth:`start` —
    useful for deterministic tests and for pre-loading a queue so the
    very first flush already packs full batches.
    """

    def __init__(self, run_batch: Callable[[Hashable, List[Any]], List[Any]],
                 policy: BatchPolicy | None = None, *, name: str = "batcher",
                 autostart: bool = True):
        self._run_batch = run_batch
        self.policy = policy or BatchPolicy()
        self.name = name
        self._cond = threading.Condition()
        self._groups: Dict[Hashable, Deque[_Job]] = collections.OrderedDict()
        self._total = 0
        self._inflight = 0
        self._accepting = True
        self._stopping = False
        self._force_flush = False
        self._thread: Optional[threading.Thread] = None
        if autostart:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "BatchScheduler":
        with self._cond:
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._worker, name=f"{self.name}-worker",
                    daemon=True)
                self._thread.start()
        return self

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting jobs; drain (wait=True) or cancel (wait=False)."""
        with self._cond:
            self._accepting = False
            self._stopping = True
            if not wait:
                for q in self._groups.values():
                    for job in q:
                        job.future.cancel()
                self._groups.clear()
                self._total = 0
            self._cond.notify_all()
            thread = self._thread
        if thread is not None and wait:
            thread.join()

    def __enter__(self) -> "BatchScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=True)

    # -- admission -----------------------------------------------------------
    def submit(self, key: Hashable, payload: Any) -> Future:
        """Enqueue one job; its Future resolves when its batch executes."""
        job = _Job(key, payload)
        with self._cond:
            if not self._accepting:
                raise RuntimeError(f"{self.name}: scheduler is shut down")
            if self._total >= self.policy.max_queue:
                if self.policy.overflow == "reject":
                    raise QueueFullError(
                        f"{self.name}: queue full "
                        f"({self._total}/{self.policy.max_queue} jobs)")
                while self._total >= self.policy.max_queue and self._accepting:
                    self._cond.wait()
                if not self._accepting:
                    raise RuntimeError(f"{self.name}: scheduler shut down "
                                       "while waiting for queue space")
            self._groups.setdefault(key, collections.deque()).append(job)
            self._total += 1
            self._cond.notify_all()
        return job.future

    def drain(self) -> None:
        """Flush every queued job now and block until all have executed."""
        with self._cond:
            self._force_flush = True
            self._cond.notify_all()
            while self._total > 0 or self._inflight > 0:
                self._cond.wait()
            self._force_flush = False

    # -- introspection -------------------------------------------------------
    def queue_depth(self, key: Hashable | None = None) -> int:
        with self._cond:
            if key is None:
                return self._total
            return len(self._groups.get(key, ()))

    # -- worker --------------------------------------------------------------
    def _pop_ready_locked(self, now: float) -> Optional[Tuple[Hashable, List[_Job]]]:
        """The first group that is full, stale, or force-flushed; else None."""
        max_wait = self.policy.max_wait_ms / 1e3
        ready = None
        for key, q in self._groups.items():
            if len(q) >= self.policy.max_batch:
                ready = key
                break
            if self._force_flush or self._stopping:
                ready = key
                break
            if now - q[0].t_submit >= max_wait:
                ready = key
                break
        if ready is None:
            return None
        q = self._groups[ready]
        batch = [q.popleft() for _ in range(min(len(q), self.policy.max_batch))]
        if not q:
            del self._groups[ready]
        self._total -= len(batch)
        self._cond.notify_all()          # wake blocked submitters
        return ready, batch

    def _next_deadline_locked(self, now: float) -> Optional[float]:
        max_wait = self.policy.max_wait_ms / 1e3
        deadlines = [q[0].t_submit + max_wait - now
                     for q in self._groups.values()]
        return max(0.0, min(deadlines)) if deadlines else None

    def _worker(self) -> None:
        while True:
            with self._cond:
                while True:
                    popped = self._pop_ready_locked(time.monotonic())
                    if popped is not None:
                        break
                    if self._stopping and self._total == 0:
                        return
                    self._cond.wait(self._next_deadline_locked(time.monotonic()))
                self._inflight += 1
            key, batch = popped
            try:
                self._execute(key, batch)
            finally:
                with self._cond:
                    self._inflight -= 1
                    self._cond.notify_all()

    def _execute(self, key: Hashable, batch: List[_Job]) -> None:
        live = [j for j in batch if j.future.set_running_or_notify_cancel()]
        if not live:
            return
        try:
            results = self._run_batch(key, [j.payload for j in live])
        except BaseException as exc:       # noqa: BLE001 — forwarded to callers
            for j in live:
                j.future.set_exception(exc)
            return
        if results is None or len(results) != len(live):
            exc = RuntimeError(
                f"{self.name}: run_batch returned "
                f"{0 if results is None else len(results)} results "
                f"for {len(live)} jobs (key={key!r})")
            for j in live:
                j.future.set_exception(exc)
            return
        for j, r in zip(live, results):
            j.future.set_result(r)
