"""Stencil-serving driver: continuous batching of tuned stencil jobs.

The production scenario behind SPIDER's "zero runtime overhead" claim is
many concurrent users each submitting a *modest* grid — not one giant
one.  Executing those jobs one ``tuned_apply`` at a time leaves the
device idle between dispatches; this driver packs them into
``tuned_apply_batched`` (jit·vmap) super-batches instead:

    driver = StencilDriver()                       # shares default_cache()
    fut = driver.submit(spec, x)                   # x includes the halo
    y = fut.result()                               # interior update

Scheduling happens on the shared :class:`~repro.serving.scheduler.
BatchScheduler` layer (the same one LM decode traffic uses, see
`serving/lm_driver.py`):

  * Jobs are bucketed by **tuner plan key** — spec content fingerprint
    × halo-inclusive shape bucket (next pow2 per dim) × dtype × device
    × coefficient mode × temporal block size × partition geometry — so
    every batch runs one compiled program under one tuned plan (a
    ``temporal_steps=k`` job carries the k·r halo and never co-batches
    with single-step jobs; a driver constructed with ``mesh=`` runs
    every job halo-exchange-sharded and buckets apart from
    single-device traffic).
  * ``padding`` policy decides how near-miss shapes inside a bucket
    co-batch: ``"bucket"`` trailing-pads every job to the pow2 bucket
    shape (one compiled program per plan, some wasted FLOPs), ``"max"``
    pads to the batch's elementwise max shape (minimal waste, jit
    re-specializes per distinct max), ``"exact"`` only batches
    identical shapes (zero waste, most fragmentation).  Trailing
    padding is correct because output row j along any dim reads input
    rows [j, j+2r] only — cropping the output back to the job's own
    interior never touches pad-contaminated values.
  * ``BatchPolicy(max_batch, max_wait_ms, max_queue, overflow)``
    controls the batch/latency/backpressure tradeoff.

``driver.metrics()`` reports, per plan group: queue depth, batch
occupancy, padding efficiency, p50/p99 latency, reject counts — plus
the tuner's ``PlanCache.stats`` (plan hit rates, engine builds).
"""
from __future__ import annotations

import time
from concurrent.futures import Future
from typing import Iterable, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.stencil import StencilSpec
from repro.serving.metrics import MetricsRegistry, merged_latency
from repro.serving.scheduler import BatchPolicy, BatchScheduler, QueueFullError
from repro.tuner.api import batch_group_key, tuned_apply_batched
from repro.tuner.cache import PlanCache, default_cache
from repro.tuner.plan import shape_bucket

PADDING_POLICIES = ("bucket", "max", "exact")


class _StencilJob:
    __slots__ = ("x", "t_submit")

    def __init__(self, x):
        self.x = x
        self.t_submit = time.monotonic()


class StencilDriver:
    """Continuous-batching front end over ``tuned_apply_batched``.

    Thread-safe: ``submit`` may be called from any number of caller
    threads; batches execute on one scheduler worker so the tuner cache
    is only ever touched single-threaded.
    """

    def __init__(self, *, cache: PlanCache | None = None,
                 policy: BatchPolicy | None = None,
                 padding: str = "bucket",
                 mode: str | None = None,
                 mesh=None,
                 autostart: bool = True):
        if padding not in PADDING_POLICIES:
            raise ValueError(f"padding must be one of {PADDING_POLICIES}, "
                             f"got {padding!r}")
        self.cache = cache if cache is not None else default_cache()
        self.padding = padding
        self.mode = mode
        # a driver with a mesh partitions EVERY job's grid over it with
        # halo exchange (distributed/halo.py); the plan key's mesh field
        # buckets these jobs apart from single-device traffic, so a
        # sharded fleet and a single-device fleet sharing one cache file
        # never serve each other's plans
        self.mesh = mesh
        self.metrics_registry = MetricsRegistry()
        self._specs: dict = {}          # group key -> StencilSpec
        self._steps: dict = {}          # group key -> temporal block size
        self._sched = BatchScheduler(self._run_batch, policy,
                                     name="stencil-driver",
                                     autostart=autostart)

    # -- admission -----------------------------------------------------------
    def group_key(self, spec: StencilSpec, x,
                  temporal_steps: int = 1) -> str:
        """The batch group ``(spec, x)`` lands in (tuner plan key string)."""
        key = batch_group_key(spec, x.shape, x.dtype,
                              temporal_steps=temporal_steps, mesh=self.mesh)
        if self.padding == "exact":
            key += ";exact=" + "x".join(str(s) for s in x.shape)
        return key

    def submit(self, spec: StencilSpec, x,
               temporal_steps: int = 1) -> Future:
        """Enqueue one job; the Future resolves to the interior update.

        ``temporal_steps=k`` advances the job k steps in one fused
        program; ``x`` must then carry the k·r halo.
        """
        x = jnp.asarray(x)
        if temporal_steps < 1:
            raise ValueError(
                f"temporal_steps must be >= 1, got {temporal_steps}")
        if x.ndim != spec.ndim:
            raise ValueError(
                f"job array must be {spec.ndim}-D (halo-inclusive) for "
                f"{spec.name}, got shape {tuple(x.shape)}")
        halo = 2 * spec.radius * temporal_steps
        if any(s <= halo for s in x.shape):
            raise ValueError(
                f"every dim must exceed the halo 2kr={halo} for "
                f"{spec.name}, got shape {tuple(x.shape)}")
        key = self.group_key(spec, x, temporal_steps)
        m = self.metrics_registry.group(key)
        self._specs.setdefault(key, spec)
        self._steps.setdefault(key, temporal_steps)
        try:
            fut = self._sched.submit(key, _StencilJob(x))
        except QueueFullError:
            m.bump(rejected=1)
            raise
        m.bump(submitted=1)
        return fut

    def map(self, jobs: Iterable[Tuple[StencilSpec, "jnp.ndarray"]],
            timeout: float | None = None) -> List["jnp.ndarray"]:
        """Submit every ``(spec, x)`` job and wait; results in input order."""
        futures = [self.submit(spec, x) for spec, x in jobs]
        return [f.result(timeout=timeout) for f in futures]

    # -- lifecycle / introspection -------------------------------------------
    def start(self) -> "StencilDriver":
        self._sched.start()
        return self

    def drain(self) -> None:
        self._sched.drain()

    def close(self, wait: bool = True) -> None:
        self._sched.shutdown(wait=wait)

    def __enter__(self) -> "StencilDriver":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(wait=True)

    def queue_depth(self, key: str | None = None) -> int:
        return self._sched.queue_depth(key)

    def metrics(self) -> dict:
        """Per-plan admission metrics + aggregate + tuner cache stats."""
        groups = [self.metrics_registry.group(k)
                  for k in self.metrics_registry.keys()]
        overall = self.metrics_registry.totals()
        overall["latency"] = merged_latency(groups).as_dict()
        overall["queue_depth"] = self.queue_depth()
        return {
            "padding": self.padding,
            "policy": {
                "max_batch": self._sched.policy.max_batch,
                "max_wait_ms": self._sched.policy.max_wait_ms,
                "max_queue": self._sched.policy.max_queue,
                "overflow": self._sched.policy.overflow,
            },
            "overall": overall,
            "plans": self.metrics_registry.as_dict(
                queue_depth=self._sched.queue_depth),
            "tuner": self.cache.stats.as_dict(),
        }

    # -- execution -----------------------------------------------------------
    def _target_shape(self, key: str,
                      shapes: Sequence[Tuple[int, ...]]) -> Tuple[int, ...]:
        if self.padding == "bucket":
            return shape_bucket(shapes[0])
        if self.padding == "max":
            return tuple(int(m) for m in np.max(np.asarray(shapes), axis=0))
        return shapes[0]                      # "exact": all identical by key

    def _run_batch(self, key: str, jobs: List[_StencilJob]) -> list:
        spec = self._specs[key]
        steps = self._steps.get(key, 1)
        m = self.metrics_registry.group(key)
        shapes = [tuple(j.x.shape) for j in jobs]
        target = self._target_shape(key, shapes)
        try:
            xs = jnp.stack([
                jnp.pad(j.x, [(0, t - s) for s, t in zip(j.x.shape, target)])
                for j in jobs])
            ys = tuned_apply_batched(spec, xs, cache=self.cache,
                                     mode=self.mode, temporal_steps=steps,
                                     mesh=self.mesh)
        except BaseException:
            m.bump(failed=len(jobs))
            raise
        halo = 2 * spec.radius * steps
        results = []
        for i, shape in enumerate(shapes):
            crop = tuple(slice(0, s - halo) for s in shape)
            results.append(ys[i][crop])
        if results:
            results[-1].block_until_ready()
        now = time.monotonic()
        m.bump(batches=1, batched_jobs=len(jobs), completed=len(jobs),
               payload_elems=int(sum(int(np.prod(s)) for s in shapes)),
               padded_elems=int(np.prod(target)) * len(jobs))
        for j in jobs:
            m.observe_latency(now - j.t_submit)
        return results
