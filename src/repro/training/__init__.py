from repro.training import checkpoint, data, fault_tolerance, optimizer
from repro.training.train_step import (TrainConfig, TrainState, init_state,
                                       make_train_step, train_step)

__all__ = ["checkpoint", "data", "fault_tolerance", "optimizer",
           "TrainConfig", "TrainState", "init_state", "make_train_step",
           "train_step"]
