"""Sharded, atomic, elastically-restorable checkpointing.

Design (tensorstore-free, works on any POSIX FS / fuse-mounted object store):

  step_<N>.tmp/            written first
    manifest.json          step, tree structure, per-leaf shape/dtype,
                           logical axes, world summary
    <leaf-path>.npy        one file per pytree leaf (full array assembled
                           from addressable shards)
  step_<N>/                atomic os.replace of the .tmp dir == commit

Fault tolerance:
  * a crash mid-write leaves only a .tmp dir -> ignored by restore;
  * restore() re-shards to ANY mesh (elastic N->M): leaves are loaded as
    full arrays and device_put against the *target* sharding, so a job can
    restart on a different pod count;
  * retention keeps the newest K checkpoints (bounded disk);
  * async commit: save() can run in a background thread so the train loop
    overlaps step N+1 compute with step N I/O (straggler-tolerant hosts
    simply lag the commit, never the step).

On multi-host deployments each host writes only the shards it owns
(process_index stripes the leaf list); this container is single-process so
the stripe is everything.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/__{i}"))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any], skeleton: Any, prefix: str = "") -> Any:
    if isinstance(skeleton, dict):
        return {k: _unflatten(flat, skeleton[k], f"{prefix}/{k}")
                for k in skeleton}
    if isinstance(skeleton, (tuple, list)):
        vals = [_unflatten(flat, v, f"{prefix}/__{i}")
                for i, v in enumerate(skeleton)]
        return type(skeleton)(vals)
    return flat[prefix]


def _leaf_file(path: str) -> str:
    return path.strip("/").replace("/", ".") + ".npy"


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically write checkpoint for ``step``. Returns the commit path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}, "leaves": {}}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = _leaf_file(path)
        np.save(os.path.join(tmp, fn), arr)
        meta["leaves"][path] = {"file": fn, "shape": list(arr.shape),
                                "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                     # atomic commit
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, MANIFEST))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, skeleton: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
    """Load a checkpoint into ``skeleton``'s structure.

    ``shardings``: optional matching tree of NamedShardings — the ELASTIC
    path: arrays are device_put against the *current* mesh regardless of the
    mesh they were saved under.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        meta = json.load(f)
    flat_skel = _flatten(skeleton)
    flat_sh = _flatten(shardings) if shardings is not None else None
    flat = {}
    for path in flat_skel:
        info = meta["leaves"][path]
        arr = np.load(os.path.join(d, info["file"]))
        if flat_sh is not None:
            flat[path] = jax.device_put(arr, flat_sh[path])
        else:
            flat[path] = jax.numpy.asarray(arr)
    return _unflatten(flat, skeleton), meta["extra"]


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()
        # materialize on host BEFORE backgrounding (device buffers may be
        # donated/overwritten by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree, extra,
                               self.keep), daemon=True)
        self._thread.start()
