"""Synthetic token pipeline — stateless, step-seeded, shard-aware.

Fault-tolerance property: batch(step) is a pure function of (seed, step),
so a restarted job resumes mid-epoch with NO data-loader state in the
checkpoint, and an elastically re-meshed job (different DP degree) still
sees the same global batch sequence — each host materializes only its
shard via ``jax.make_array_from_callback``.

The generator is a mixture of Zipfian unigrams and short repeated n-grams,
which gives a non-trivial, learnable next-token distribution (examples/
train_lm.py drives loss visibly down on it).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram: int = 8          # period of the repeated pattern


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def global_batch(dc: DataConfig, step: int) -> np.ndarray:
    """The full (B, S+1) int32 batch for a step (host-side numpy)."""
    rng = np.random.default_rng(np.uint64(dc.seed * 1_000_003 + step))
    probs = _zipf_probs(dc.vocab, dc.zipf_a)
    b, s = dc.global_batch, dc.seq_len + 1
    base = rng.choice(dc.vocab, size=(b, dc.ngram), p=probs)
    reps = -(-s // dc.ngram)
    tok = np.tile(base, (1, reps))[:, :s]
    # sprinkle noise so the task is not trivially periodic
    noise_mask = rng.random((b, s)) < 0.15
    noise = rng.choice(dc.vocab, size=(b, s), p=probs)
    tok = np.where(noise_mask, noise, tok)
    return tok.astype(np.int32)


def sharded_batch(dc: DataConfig, step: int, sharding) -> jax.Array:
    """Materialize only this host's shard of batch(step) under ``sharding``.

    On a 1000-node cluster each host generates its slice directly; there is
    no broadcast and no host-0 bottleneck.
    """
    shape = (dc.global_batch, dc.seq_len + 1)
    full = None

    def cb(index):
        nonlocal full
        if full is None:
            full = global_batch(dc, step)
        return full[index]

    return jax.make_array_from_callback(shape, sharding, cb)


def batch_iterator(dc: DataConfig, sharding, start_step: int = 0):
    step = start_step
    while True:
        yield step, sharded_batch(dc, step, sharding)
        step += 1
