"""Fault tolerance + elasticity + straggler mitigation.

What is mechanically implemented and tested in this repo:
  * atomic resumable checkpoints (training/checkpoint.py) — crash-consistent
    commit via os.replace; restore() re-shards to the CURRENT mesh
    (elastic N->M data shards) because leaves are assembled full and
    device_put against target NamedShardings;
  * stateless step-seeded data (training/data.py) — resume needs only the
    step counter, and a re-meshed job slices the identical global batch;
  * async checkpoint I/O overlapped with compute (AsyncCheckpointer);
  * the supervisor loop below: detect device-count change -> rebuild mesh,
    re-lower the step, restore latest checkpoint, continue.

What a 1000+-node deployment adds operationally (documented hooks, no code
dependency):
  * health: jax.distributed heartbeats; a missing host fails
    initialization -> the scheduler restarts the job at N' hosts and the
    elastic restore path above takes over (that path IS exercised in
    tests/test_fault_tolerance.py by changing mesh shape between save and
    restore);
  * stragglers: with synchronous SPMD the slowest chip paces the step;
    mitigations wired here: (a) async checkpointing off the critical path,
    (b) fixed-shape step graphs (no data-dependent recompile stalls),
    (c) step-time watchdog that flags hosts whose local dispatch lags the
    fleet median by > straggler_factor for eviction-and-restart — eviction
    is the scheduler's job, detection is ours.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax

from repro.training import checkpoint as ckpt


@dataclasses.dataclass
class Watchdog:
    """Step-time straggler detector (host-side, zero device overhead)."""
    straggler_factor: float = 2.0
    window: int = 50
    _times: List[float] = dataclasses.field(default_factory=list)

    def record(self, seconds: float) -> bool:
        """Record a step time; True if this step is a straggler outlier."""
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 5:
            return False
        med = sorted(self._times)[len(self._times) // 2]
        return seconds > self.straggler_factor * med


@dataclasses.dataclass
class Supervisor:
    """Restart-survivable training driver state machine.

    make_world(): builds (mesh, sharded step fn, state shardings) for the
    CURRENT device fleet. On any fault (or detected fleet change) the loop
    rebuilds the world and restores the newest checkpoint into it.
    """
    ckpt_dir: str
    make_world: Callable[[], Dict]
    save_every: int = 100
    keep: int = 3

    def run(self, total_steps: int, step_fn_key: str = "step",
            on_metrics: Optional[Callable] = None) -> Dict:
        world = self.make_world()
        saver = ckpt.AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
        state = world["state"]
        start = ckpt.latest_step(self.ckpt_dir)
        if start is not None:
            state, extra = ckpt.restore(
                self.ckpt_dir, jax.tree.map(lambda x: x, state),
                shardings=world.get("state_shardings"))
            start = int(extra.get("step", start))
        else:
            start = 0
        wd = Watchdog()
        n_devices = jax.device_count()
        step = start
        while step < total_steps:
            if jax.device_count() != n_devices:   # elastic fleet change
                world = self.make_world()
                state, extra = ckpt.restore(
                    self.ckpt_dir, world["state"],
                    shardings=world.get("state_shardings"))
                step = int(extra.get("step", step))
                n_devices = jax.device_count()
            t0 = time.monotonic()
            state, metrics = world[step_fn_key](state, world["batch"](step))
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            if wd.record(time.monotonic() - t0):
                metrics = dict(metrics)
                metrics["straggler_flag"] = True
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % self.save_every == 0 or step == total_steps:
                saver.save(step, state if not hasattr(state, "tree")
                           else state.tree(), extra={"step": step})
        saver.wait()
        return {"state": state, "final_step": step}
