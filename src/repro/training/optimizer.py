"""AdamW with fp32 master state, global-norm clipping, cosine schedule.

No optax dependency — the optimizer is a pure pytree transform so its state
inherits the params' logical-axis sharding (ZeRO: moments are sharded
exactly like the FSDP weight shards; under pjit this happens automatically
because the state tree carries the same NamedShardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray        # () int32
    mu: Any                  # fp32 first moment, params-shaped
    nu: Any                  # fp32 second moment
    master: Any              # fp32 master weights


def schedule(oc: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps) /
                    jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * cos


def init(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        # copy=True: fp32 params must NOT alias master (donation safety)
        master=jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(oc: OptConfig, state: OptState, grads, compute_dtype) -> Tuple[Any, OptState, Dict]:
    """One AdamW step. grads are fp32 (cast by the caller); returns new
    compute-dtype params + new state + metrics."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(oc, step)
    b1, b2 = oc.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        vhat = nu / bc2
        m = m - lr * (mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * m)
        return mu, nu, m

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_m = treedef.flatten_up_to(state.master)
    out = [upd(g, mu, nu, m) for g, mu, nu, m in
           zip(flat_g, flat_mu, flat_nu, flat_m)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    params = jax.tree.map(lambda m: m.astype(compute_dtype), master)
    new_state = OptState(step=step, mu=mu, nu=nu, master=master)
    return params, new_state, {"grad_norm": gnorm, "lr": lr}
