"""The pjit-able train step: grad-accum microbatching, mixed precision,
AdamW, optional bf16 cross-pod gradient compression.

This is the graph the dry-run lowers for every ``train_4k`` cell. All
distribution is expressed through shardings on params/opt-state/batch plus
logical-axis constraints inside the model; XLA GSPMD inserts the
collectives, and expressing FSDP as reduce-scatter(grads) + all-gather
(params) lets the scheduler overlap them with backward/forward compute.

Distributed-optimization tricks implemented here:
  * ZeRO-3 (FSDP): params/master/moments sharded over 'data' via the
    logical-axis rules; nothing in this file special-cases it.
  * microbatch grad accumulation: lax.scan over the leading microbatch
    axis, fp32 accumulator (the per-microbatch remat graph is the unit the
    compiler pipelines).
  * hierarchical / compressed cross-pod reduction: gradients for the pod
    axis can be cast to bf16 before the cross-DCN reduce (grad_compress),
    halving the slowest collective's bytes; fp32 restore before AdamW.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import optimizer as O


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1          # grad-accum steps per train step
    aux_weight: float = 0.01
    grad_compress: bool = False    # bf16 gradient tree before reduction
    opt: O.OptConfig = dataclasses.field(default_factory=O.OptConfig)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: O.OptState

    def tree(self):
        return {"params": self.params, "opt": self.opt._asdict()}


def init_state(cfg: ModelConfig, key: jax.Array) -> Tuple[TrainState, Dict]:
    params, axes = M.init_params(cfg, key)
    return TrainState(params=params, opt=O.init(params)), axes


def _microbatch(tokens: jnp.ndarray, n: int, memory):
    """(B, S) -> (n, B/n, S), leading microbatch axis for lax.scan."""
    b = tokens.shape[0]
    assert b % n == 0, f"global batch {b} % microbatches {n} != 0"
    tok = tokens.reshape(n, b // n, *tokens.shape[1:])
    mem = None
    if memory is not None:
        mem = memory.reshape(n, b // n, *memory.shape[1:])
    return tok, mem


def loss_and_grads(cfg: ModelConfig, tc: TrainConfig, params,
                   tokens, memory=None):
    """fp32 grad tree accumulated over microbatches."""
    def one(p, tok, mem):
        def lf(p_):
            return M.lm_loss(p_, cfg, tok, memory=mem,
                             aux_weight=tc.aux_weight)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(p)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return loss, metrics, grads

    if tc.microbatches == 1:
        return one(params, tokens, memory)

    tok_mb, mem_mb = _microbatch(tokens, tc.microbatches, memory)
    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(acc, xs):
        loss_a, grads_a = acc
        tok = xs if mem_mb is None else xs[0]
        mem = None if mem_mb is None else xs[1]
        loss, metrics, grads = one(params, tok, mem)
        grads_a = jax.tree.map(jnp.add, grads_a, grads)
        return (loss_a + loss, grads_a), metrics

    xs = tok_mb if mem_mb is None else (tok_mb, mem_mb)
    (loss_sum, grads), metrics = jax.lax.scan(body, (0.0, zero), xs)
    inv = 1.0 / tc.microbatches
    grads = jax.tree.map(lambda g: g * inv, grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum * inv, metrics, grads


def train_step(cfg: ModelConfig, tc: TrainConfig, state: TrainState,
               tokens: jnp.ndarray, memory=None
               ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    """One full optimizer step. jit/pjit this with donated state."""
    loss, metrics, grads = loss_and_grads(cfg, tc, state.params, tokens,
                                          memory)
    if tc.grad_compress:
        # Cross-pod gradient compression: round-trip through bf16 so the
        # slow (DCN) reduction moves half the bytes. Under GSPMD the cast
        # happens before the all-reduce that the sharding propagation
        # places; numerics: bf16 mantissa on an already-averaged tree.
        grads = jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    params, opt, opt_metrics = O.apply(tc.opt, state.opt, grads, dt)
    out = {"loss": loss, **metrics, **opt_metrics}
    return TrainState(params=params, opt=opt), out


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Partial with static configs bound — the callable handed to jit."""
    @functools.wraps(train_step)
    def step(state, tokens, memory=None):
        return train_step(cfg, tc, state, tokens, memory)
    return step
