"""repro.tuner — autotuning, plan cache, and batched execution.

SPIDER's contract is "slight compile-time cost, zero runtime cost"
(paper §3): every transformation — strided swap, 2:4 encoding, kernel
matrix construction — happens before the first stencil application.
This package extends that contract to *configuration*: which backend,
which tile size ``L``, whether to fuse kernel rows.  The winning choice
depends on stencil shape/radius, problem size, dtype and device kind
(ConvStencil and FlashSparse both tune over exactly this space), so it
is measured once, cached, and persisted — never guessed per call.

Layers:
  plan.py    Plan (backend, L, fuse_rows, star_fast_path) and the cache
             key: (spec fingerprint, shape bucket, dtype, device kind).
  search.py  candidate enumeration + warmup/median timing autotuner with
             a static cost-model fallback (reuses core/analysis.py ideas).
  cache.py   in-memory plan + compiled-engine cache with JSON persistence.
  api.py     tuned_apply / tuned_apply_batched / tuned_engine / plan_for.
"""
from repro.tuner.api import (batch_group_key, cache_stats, clear_cache,
                             plan_for, tuned_apply, tuned_apply_batched,
                             tuned_engine)
from repro.tuner.cache import PlanCache, default_cache, reset_default_cache
from repro.tuner.plan import (Plan, PlanKey, plan_key, shape_bucket,
                              spec_fingerprint)
from repro.tuner.search import TuneResult, autotune, candidate_plans, static_cost

__all__ = [
    "Plan", "PlanKey", "PlanCache", "TuneResult",
    "autotune", "batch_group_key", "cache_stats", "candidate_plans",
    "clear_cache",
    "default_cache", "plan_for", "plan_key", "reset_default_cache",
    "shape_bucket", "spec_fingerprint", "static_cost",
    "tuned_apply", "tuned_apply_batched", "tuned_engine",
]
