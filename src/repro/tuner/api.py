"""Public tuner entry points.

    from repro.tuner import tuned_apply
    y = tuned_apply(spec, x)          # tunes once, then cache-hits forever

``mode`` selects how a missing plan is chosen: ``"time"`` (measure
candidates; the default) or ``"cost"`` (static model, no builds).  The
``REPRO_TUNER_MODE`` env var overrides the default for processes where
timing is undesirable (CI, dry-runs).
"""
from __future__ import annotations

import os
from typing import Any, Sequence

import jax.numpy as jnp

from repro.core.engine import StencilEngine
from repro.core.stencil import StencilSpec
from repro.tuner.cache import PlanCache, default_cache
from repro.tuner.plan import Plan, mesh_desc, plan_key
from repro.tuner.search import autotune

MODE_ENV_VAR = "REPRO_TUNER_MODE"


def _resolve_mode(mode: str | None) -> str:
    return mode or os.environ.get(MODE_ENV_VAR, "time")


def _is_sharded(mesh: Any) -> bool:
    return mesh is not None and mesh_desc(mesh) != "1"


def plan_for(spec: StencilSpec, shape: Sequence[int],
             dtype: Any = jnp.float32, *,
             cache: PlanCache | None = None, mode: str | None = None,
             temporal_steps: int = 1, coefficients: Any = None,
             mesh: Any = None,
             warmup: int = 1, iters: int = 3) -> Plan:
    """The cached plan for (spec, halo-inclusive shape, dtype); tunes on miss.

    ``temporal_steps`` and ``coefficients`` extend the cache key (and the
    candidate set): a k-step temporal block tunes separately from the
    single-step plan, and a variable-coefficient field tunes per content
    fingerprint over the backends that support it.  ``mesh`` (a jax Mesh
    or per-axis shard counts) keys and tunes the halo-exchange-sharded
    execution path separately — per-shard blocks see different shapes
    and communication costs, so a single-device plan must never be
    served to a sharded run or vice versa.
    """
    cache = cache if cache is not None else default_cache()
    key = plan_key(spec, tuple(shape), dtype, coefficients=coefficients,
                   temporal_steps=temporal_steps, mesh=mesh)
    plan = cache.lookup(key)
    if plan is None:
        if _is_sharded(mesh):
            if coefficients is not None:
                raise NotImplementedError(
                    "variable-coefficient stencils are not supported on the "
                    "sharded halo-exchange path (the per-field tables are "
                    "fixed to the global shape)")

            def factory(s: StencilSpec, p: Plan,
                        coefficients: Any = None) -> Any:
                return cache.sharded_engine(s, p, mesh)
        else:
            factory = cache.engine
        before = cache.engine_plans(spec)
        result = autotune(spec, tuple(shape), dtype, mode=_resolve_mode(mode),
                          engine_factory=factory,
                          temporal_steps=temporal_steps,
                          coefficients=coefficients,
                          warmup=warmup, iters=iters)
        cache.stats.tunes += 1
        plan = result.plan
        cache.store(key, plan)
        # keep the (already warm) winner plus anything cached before the
        # tune; losing candidates' compiled engines are dead weight
        cache.prune_engines(spec, keep=before | {plan})
    return plan


def tuned_engine(spec: StencilSpec, shape: Sequence[int],
                 dtype: Any = jnp.float32, *,
                 cache: PlanCache | None = None, mode: str | None = None,
                 temporal_steps: int = 1, coefficients: Any = None,
                 mesh: Any = None,
                 warmup: int = 1, iters: int = 3) -> Any:
    """Compiled engine for the tuned plan (shared jit cache across calls).

    With a non-trivial ``mesh`` this is a
    :class:`~repro.distributed.halo.ShardedStencilEngine` (same
    halo-inclusive call convention); otherwise a ``StencilEngine``.
    """
    cache = cache if cache is not None else default_cache()
    plan = plan_for(spec, shape, dtype, cache=cache, mode=mode,
                    temporal_steps=temporal_steps, coefficients=coefficients,
                    mesh=mesh, warmup=warmup, iters=iters)
    if _is_sharded(mesh):
        return cache.sharded_engine(spec, plan, mesh)
    return cache.engine(spec, plan, coefficients=coefficients)


def tuned_apply(spec: StencilSpec, x: jnp.ndarray, *,
                cache: PlanCache | None = None,
                mode: str | None = None, temporal_steps: int = 1,
                coefficients: Any = None, mesh: Any = None,
                warmup: int = 1, iters: int = 3) -> jnp.ndarray:
    """Apply ``spec`` to ``x`` (halo included) through the tuned plan.

    A ``temporal_steps=k`` call expects ``x`` to carry the ``k·r`` halo
    and advances k steps in one compiled program; ``coefficients`` routes
    through the variable-coefficient emitter (fixed-shape per field);
    ``mesh`` block-partitions the grid over a device mesh with halo
    exchange (`distributed/halo.py`).
    """
    eng = tuned_engine(spec, x.shape, x.dtype, cache=cache, mode=mode,
                       temporal_steps=temporal_steps,
                       coefficients=coefficients, mesh=mesh,
                       warmup=warmup, iters=iters)
    return eng(x)


def _validate_batch(spec: StencilSpec, xs: Any,
                    temporal_steps: int = 1) -> jnp.ndarray:
    """Normalize ``xs`` to one stacked (B, *spatial) array, loudly.

    Accepts a pre-stacked array or any iterable of per-job arrays
    (lists, tuples, generators, map objects — a non-array iterable is
    materialized first, so a generator doesn't fall through to
    ``jnp.asarray`` and die deep inside JAX).  Every job must share ONE
    shape and dtype — a jit(vmap) program is shape-monomorphic — and
    mismatches name the offending shapes instead of failing deep inside
    ``jnp.stack``/``vmap``.
    """
    if not isinstance(xs, (list, tuple)) and not hasattr(xs, "ndim"):
        try:
            xs = list(xs)
        except TypeError:
            raise TypeError(
                "tuned_apply_batched expects a stacked (B, *spatial) array "
                "or an iterable of per-job arrays, got "
                f"{type(xs).__name__}") from None
    if isinstance(xs, (list, tuple)):
        if not xs:
            raise ValueError("tuned_apply_batched got an empty batch")
        arrs = [jnp.asarray(x) for x in xs]
        shapes = [tuple(a.shape) for a in arrs]
        if len(set(shapes)) > 1:
            first = shapes[0]
            bad = next((i, s) for i, s in enumerate(shapes) if s != first)
            raise ValueError(
                "tuned_apply_batched requires every job to share one shape "
                f"(pad or bucket them first — see serving/stencil_driver.py): "
                f"job 0 has shape {first} but job {bad[0]} has shape {bad[1]}; "
                f"distinct shapes: {sorted(set(shapes))}")
        dtypes = sorted({str(a.dtype) for a in arrs})
        if len(dtypes) > 1:
            raise ValueError(
                "tuned_apply_batched requires every job to share one dtype; "
                f"got {dtypes}")
        xs = jnp.stack(arrs)
    if xs.ndim != spec.ndim + 1:
        raise ValueError(
            f"tuned_apply_batched expects (B, *spatial-with-halo) with "
            f"{spec.ndim + 1} dims for {spec.name}, got shape "
            f"{tuple(xs.shape)}")
    halo = 2 * spec.radius * temporal_steps
    if any(s <= halo for s in xs.shape[1:]):
        raise ValueError(
            f"every spatial dim must exceed the halo 2kr={halo} "
            f"for {spec.name}, got batch shape {tuple(xs.shape)}")
    return xs


def tuned_apply_batched(spec: StencilSpec, xs: Any, *,
                        cache: PlanCache | None = None,
                        mode: str | None = None, temporal_steps: int = 1,
                        mesh: Any = None,
                        warmup: int = 1, iters: int = 3) -> jnp.ndarray:
    """Apply ``spec`` to a batch ``xs`` of shape (B, *spatial-with-halo).

    ``xs`` may also be an iterable of same-shape per-job arrays (it is
    validated and stacked).  The plan is tuned for one instance;
    execution is a single jit(vmap(engine)) program — the many-user
    serving path (continuously batched by `serving/stencil_driver.py`).
    With ``temporal_steps=k`` every job advances k steps (jobs carry the
    k·r halo).  With a non-trivial ``mesh`` every job's grid is block-
    partitioned over the device mesh with halo exchange (the batch axis
    itself stays unsharded).
    """
    cache = cache if cache is not None else default_cache()
    xs = _validate_batch(spec, xs, temporal_steps=temporal_steps)
    plan = plan_for(spec, tuple(xs.shape[1:]), xs.dtype, cache=cache,
                    mode=mode, temporal_steps=temporal_steps, mesh=mesh,
                    warmup=warmup, iters=iters)
    if _is_sharded(mesh):
        return cache.sharded_batched(spec, plan, mesh)(xs)
    return cache.batched(spec, plan)(xs)


def batch_group_key(spec: StencilSpec, shape: Sequence[int], dtype: Any,
                    device: str | None = None, *,
                    temporal_steps: int = 1, mesh: Any = None) -> str:
    """Stable string key a serving driver buckets batchable jobs by.

    Two jobs with equal keys share one tuned plan AND one compiled
    jit(vmap) program once padded to the bucket shape: the key is the
    encoded :class:`~repro.tuner.plan.PlanKey` (spec fingerprint ×
    halo-inclusive shape bucket × dtype × device kind × coefficient
    mode × temporal block size × partition geometry — sharded jobs
    never co-batch with single-device jobs).
    """
    return plan_key(spec, tuple(shape), dtype, device,
                    temporal_steps=temporal_steps, mesh=mesh).encode()


def cache_stats(cache: PlanCache | None = None) -> dict:
    cache = cache if cache is not None else default_cache()
    return cache.stats.as_dict()


def clear_cache(cache: PlanCache | None = None,
                remove_file: bool = False) -> None:
    cache = cache if cache is not None else default_cache()
    cache.clear(remove_file=remove_file)
