"""Public tuner entry points.

    from repro.tuner import tuned_apply
    y = tuned_apply(spec, x)          # tunes once, then cache-hits forever

``mode`` selects how a missing plan is chosen: ``"time"`` (measure
candidates; the default) or ``"cost"`` (static model, no builds).  The
``REPRO_TUNER_MODE`` env var overrides the default for processes where
timing is undesirable (CI, dry-runs).
"""
from __future__ import annotations

import os
from typing import Sequence

import jax.numpy as jnp

from repro.core.engine import StencilEngine
from repro.core.stencil import StencilSpec
from repro.tuner.cache import PlanCache, default_cache
from repro.tuner.plan import Plan, plan_key
from repro.tuner.search import autotune

MODE_ENV_VAR = "REPRO_TUNER_MODE"


def _resolve_mode(mode: str | None) -> str:
    return mode or os.environ.get(MODE_ENV_VAR, "time")


def plan_for(spec: StencilSpec, shape: Sequence[int], dtype=jnp.float32, *,
             cache: PlanCache | None = None, mode: str | None = None,
             warmup: int = 1, iters: int = 3) -> Plan:
    """The cached plan for (spec, halo-inclusive shape, dtype); tunes on miss."""
    cache = cache if cache is not None else default_cache()
    key = plan_key(spec, tuple(shape), dtype)
    plan = cache.lookup(key)
    if plan is None:
        before = cache.engine_plans(spec)
        result = autotune(spec, tuple(shape), dtype, mode=_resolve_mode(mode),
                          engine_factory=cache.engine,
                          warmup=warmup, iters=iters)
        cache.stats.tunes += 1
        plan = result.plan
        cache.store(key, plan)
        # keep the (already warm) winner plus anything cached before the
        # tune; losing candidates' compiled engines are dead weight
        cache.prune_engines(spec, keep=before | {plan})
    return plan


def tuned_engine(spec: StencilSpec, shape: Sequence[int], dtype=jnp.float32, *,
                 cache: PlanCache | None = None, mode: str | None = None,
                 warmup: int = 1, iters: int = 3) -> StencilEngine:
    """Compiled engine for the tuned plan (shared jit cache across calls)."""
    cache = cache if cache is not None else default_cache()
    plan = plan_for(spec, shape, dtype, cache=cache, mode=mode,
                    warmup=warmup, iters=iters)
    return cache.engine(spec, plan)


def tuned_apply(spec: StencilSpec, x, *, cache: PlanCache | None = None,
                mode: str | None = None, warmup: int = 1, iters: int = 3):
    """Apply ``spec`` to ``x`` (halo included) through the tuned plan."""
    eng = tuned_engine(spec, x.shape, x.dtype, cache=cache, mode=mode,
                       warmup=warmup, iters=iters)
    return eng(x)


def tuned_apply_batched(spec: StencilSpec, xs, *,
                        cache: PlanCache | None = None,
                        mode: str | None = None,
                        warmup: int = 1, iters: int = 3):
    """Apply ``spec`` to a batch ``xs`` of shape (B, *spatial-with-halo).

    The plan is tuned for one instance; execution is a single
    jit(vmap(engine)) program — the many-user serving path.
    """
    cache = cache if cache is not None else default_cache()
    plan = plan_for(spec, tuple(xs.shape[1:]), xs.dtype, cache=cache,
                    mode=mode, warmup=warmup, iters=iters)
    return cache.batched(spec, plan)(xs)


def cache_stats(cache: PlanCache | None = None) -> dict:
    cache = cache if cache is not None else default_cache()
    return cache.stats.as_dict()


def clear_cache(cache: PlanCache | None = None,
                remove_file: bool = False) -> None:
    cache = cache if cache is not None else default_cache()
    cache.clear(remove_file=remove_file)
