"""Plan + compiled-engine cache with JSON persistence.

Three maps, three lifetimes:

  plans     PlanKey -> Plan.  Cheap, serializable — persisted to a JSON
            file so tuning survives process restarts (set the path, or
            the ``REPRO_TUNER_CACHE`` env var for the default cache).
  engines   (spec fingerprint, Plan) -> StencilEngine.  Holds the jitted
            executable; this is what kills the re-jit-per-call pattern
            the dead ``_cached_engine`` was meant to prevent.
  batched   (spec fingerprint, Plan) -> jit(vmap(engine)).  The
            many-user entry: one compiled program for a whole batch.

Persistence format (version 1)::

    {"version": 1, "plans": {"spec=...;shape=...;dtype=...;dev=...":
                             {"backend": "sptc", "L": 8, ...}}}

Writes are atomic (tmp file + rename) so a crashed process never leaves
a truncated cache behind; unreadable files are ignored, not fatal.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.engine import StencilEngine
from repro.core.stencil import StencilSpec
from repro.tuner.plan import Plan, PlanKey, spec_fingerprint

CACHE_ENV_VAR = "REPRO_TUNER_CACHE"
_FORMAT_VERSION = 1


@dataclasses.dataclass
class CacheStats:
    plan_hits: int = 0
    plan_misses: int = 0
    tunes: int = 0
    engine_builds: int = 0
    engine_hits: int = 0
    loads: int = 0
    saves: int = 0

    @property
    def plan_hit_rate(self) -> float:
        total = self.plan_hits + self.plan_misses
        return self.plan_hits / total if total else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["plan_hit_rate"] = round(self.plan_hit_rate, 4)
        return d


class PlanCache:
    """In-memory plan + executable cache, optionally backed by a JSON file."""

    def __init__(self, path: str | os.PathLike | None = None) -> None:
        self.path: Optional[Path] = Path(path).expanduser() if path else None
        self.stats = CacheStats()
        self._plans: Dict[str, Plan] = {}
        self._engines: Dict[Tuple[str, Plan], StencilEngine] = {}
        self._batched: Dict[Tuple[str, Plan], Callable] = {}
        if self.path is not None:
            self.load(missing_ok=True)

    # -- plans ---------------------------------------------------------------
    def lookup(self, key: PlanKey) -> Optional[Plan]:
        plan = self._plans.get(key.encode())
        if plan is None:
            self.stats.plan_misses += 1
        else:
            self.stats.plan_hits += 1
        return plan

    def store(self, key: PlanKey, plan: Plan) -> None:
        self._plans[key.encode()] = plan
        if self.path is not None:
            self.save()

    def __len__(self) -> int:
        return len(self._plans)

    # -- compiled executables ------------------------------------------------
    def engine(self, spec: StencilSpec, plan: Plan) -> StencilEngine:
        """The (memoized) compiled engine realizing ``plan`` for ``spec``."""
        k = (spec_fingerprint(spec), plan)
        eng = self._engines.get(k)
        if eng is None:
            self.stats.engine_builds += 1
            eng = StencilEngine(spec, backend=plan.backend, L=plan.L,
                                star_fast_path=plan.star_fast_path,
                                fuse_rows=plan.fuse_rows)
            self._engines[k] = eng
        else:
            self.stats.engine_hits += 1
        return eng

    def engine_plans(self, spec: StencilSpec) -> frozenset:
        """Plans that currently have a cached engine for ``spec``."""
        fp = spec_fingerprint(spec)
        return frozenset(p for f, p in self._engines if f == fp)

    def prune_engines(self, spec: StencilSpec,
                      keep: "frozenset[Plan] | set[Plan]") -> int:
        """Drop cached engines for ``spec`` whose plan is not in ``keep``.

        Used after a timed tune: losing candidates' jitted executables
        would otherwise live for the cache's lifetime. Returns #dropped.
        """
        fp = spec_fingerprint(spec)
        drop = [k for k in self._engines if k[0] == fp and k[1] not in keep]
        for k in drop:
            del self._engines[k]
            self._batched.pop(k, None)
        return len(drop)

    def batched(self, spec: StencilSpec, plan: Plan) -> Callable:
        """jit(vmap(engine)) over a leading batch axis, memoized."""
        k = (spec_fingerprint(spec), plan)
        fn = self._batched.get(k)
        if fn is None:
            eng = self.engine(spec, plan)
            fn = jax.jit(jax.vmap(eng._fn))
            self._batched[k] = fn
        return fn

    # -- persistence ---------------------------------------------------------
    def save(self, path: str | os.PathLike | None = None) -> Path:
        """Atomically write all plans as JSON; returns the path written."""
        target = Path(path).expanduser() if path else self.path
        if target is None:
            raise ValueError("no persistence path set for this cache")
        target.parent.mkdir(parents=True, exist_ok=True)
        payload = {"version": _FORMAT_VERSION,
                   "plans": {k: p.to_dict() for k, p in self._plans.items()}}
        fd, tmp = tempfile.mkstemp(dir=str(target.parent),
                                   prefix=target.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, target)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.stats.saves += 1
        return target

    def load(self, path: str | os.PathLike | None = None,
             missing_ok: bool = False) -> int:
        """Merge plans from a JSON file; returns the number loaded."""
        source = Path(path).expanduser() if path else self.path
        if source is None:
            raise ValueError("no persistence path set for this cache")
        if not source.exists():
            if missing_ok:
                return 0
            raise FileNotFoundError(source)
        try:
            payload = json.loads(source.read_text())
            if payload.get("version") != _FORMAT_VERSION:
                return 0
            plans = {k: Plan.from_dict(d)
                     for k, d in payload.get("plans", {}).items()}
        except (OSError, ValueError, KeyError, TypeError):
            return 0               # corrupt/unreadable cache: retune, don't crash
        self._plans.update(plans)
        self.stats.loads += 1
        return len(plans)

    def clear(self, remove_file: bool = False) -> None:
        self._plans.clear()
        self._engines.clear()
        self._batched.clear()
        if remove_file and self.path is not None and self.path.exists():
            self.path.unlink()


# ---------------------------------------------------------------------------
# process-wide default cache
# ---------------------------------------------------------------------------

_default: Optional[PlanCache] = None


def default_cache() -> PlanCache:
    """The shared cache behind apply_stencil/tuned_apply.

    Persists iff ``REPRO_TUNER_CACHE`` names a file path at first use.
    """
    global _default
    if _default is None:
        _default = PlanCache(path=os.environ.get(CACHE_ENV_VAR) or None)
    return _default


def reset_default_cache() -> None:
    """Drop the process-wide cache (next default_cache() re-reads the env)."""
    global _default
    _default = None
