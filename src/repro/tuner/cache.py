"""Plan + compiled-engine cache with JSON persistence.

Three maps, three lifetimes:

  plans     PlanKey -> Plan.  Cheap, serializable — persisted to a JSON
            file so tuning survives process restarts (set the path, or
            the ``REPRO_TUNER_CACHE`` env var for the default cache).
  engines   (spec fingerprint, Plan, coeff fingerprint) -> StencilEngine.
            Holds the jitted executable; this is what kills the
            re-jit-per-call pattern the dead ``_cached_engine`` was meant
            to prevent.
  batched   same key -> jit(vmap(engine)).  The many-user entry: one
            compiled program for a whole batch.

Persistence format (version 2; version-1 files still load)::

    {"version": 2, "plans": {"v2;spec=...;shape=...;dtype=...;dev=...;
                             coeff=const;steps=1":
                             {"schema": 2, "backend": "sptc", "L": 8, ...}}}

Forward compatibility: a future-versioned file, or any individual entry
whose key/plan fails to decode, is skipped with a warning — never fatal
(a fleet mixing code revisions must not poison each other's caches).
Keys are re-canonicalized on load, so version-1 entries keep hitting.

Writes are atomic (tmp file + rename) and *merging*: if the file changed
on disk since this process last read it (another server tuned
concurrently), the on-disk entries are merged in first — in-memory plans
win conflicts — so a fleet converges on the union of its tuned plans.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.core.engine import StencilEngine
from repro.core.stencil import StencilSpec
from repro.tuner.plan import (Plan, PlanKey, coefficients_fingerprint,
                              mesh_desc, spec_fingerprint)

CACHE_ENV_VAR = "REPRO_TUNER_CACHE"
_FORMAT_VERSION = 2
_READABLE_VERSIONS = (1, 2)

#: engine-map key: (spec fingerprint, plan, coefficient fingerprint)
EngineKey = Tuple[str, Plan, str]

#: sharded-engine key: (spec fingerprint, plan, mesh geometry, grid axes)
ShardedKey = Tuple[str, Plan, str, Tuple[int, ...]]


@dataclasses.dataclass
class CacheStats:
    plan_hits: int = 0
    plan_misses: int = 0
    tunes: int = 0
    engine_builds: int = 0
    engine_hits: int = 0
    loads: int = 0
    saves: int = 0
    merges: int = 0
    skipped_entries: int = 0

    @property
    def plan_hit_rate(self) -> float:
        total = self.plan_hits + self.plan_misses
        return self.plan_hits / total if total else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["plan_hit_rate"] = round(self.plan_hit_rate, 4)
        return d


def _coeff_fp(coefficients: Optional[Any]) -> str:
    return ("const" if coefficients is None
            else coefficients_fingerprint(coefficients))


class PlanCache:
    """In-memory plan + executable cache, optionally backed by a JSON file."""

    def __init__(self, path: str | os.PathLike | None = None) -> None:
        self.path: Optional[Path] = Path(path).expanduser() if path else None
        self.stats = CacheStats()
        self._plans: Dict[str, Plan] = {}
        self._engines: Dict[EngineKey, StencilEngine] = {}
        self._batched: Dict[EngineKey, Callable] = {}
        self._sharded: Dict[ShardedKey, Any] = {}
        self._sharded_batched: Dict[ShardedKey, Callable] = {}
        self._disk_sig: Optional[Tuple[int, int]] = None
        if self.path is not None:
            self.load(missing_ok=True)

    # -- plans ---------------------------------------------------------------
    def lookup(self, key: PlanKey) -> Optional[Plan]:
        plan = self._plans.get(key.encode())
        if plan is None:
            self.stats.plan_misses += 1
        else:
            self.stats.plan_hits += 1
        return plan

    def store(self, key: PlanKey, plan: Plan) -> None:
        self._plans[key.encode()] = plan
        if self.path is not None:
            self.save()

    def __len__(self) -> int:
        return len(self._plans)

    # -- compiled executables ------------------------------------------------
    def engine(self, spec: StencilSpec, plan: Plan,
               coefficients: Optional[Any] = None) -> StencilEngine:
        """The (memoized) compiled engine realizing ``plan`` for ``spec``.

        Variable-coefficient engines key additionally on the coefficient
        field's content fingerprint (the jitted program bakes the values).
        """
        k = (spec_fingerprint(spec), plan, _coeff_fp(coefficients))
        eng = self._engines.get(k)
        if eng is None:
            self.stats.engine_builds += 1
            eng = StencilEngine(spec, backend=plan.backend, L=plan.L,
                                star_fast_path=plan.star_fast_path,
                                fuse_rows=plan.fuse_rows,
                                temporal_steps=plan.temporal_steps,
                                coefficients=coefficients)
            self._engines[k] = eng
        else:
            self.stats.engine_hits += 1
        return eng

    def engine_plans(self, spec: StencilSpec) -> frozenset:
        """Plans that currently have a cached engine for ``spec``."""
        fp = spec_fingerprint(spec)
        plans = {p for f, p, _ in self._engines if f == fp}
        plans.update(k[1] for k in self._sharded if k[0] == fp)
        return frozenset(plans)

    def prune_engines(self, spec: StencilSpec,
                      keep: "frozenset[Plan] | set[Plan]") -> int:
        """Drop cached engines for ``spec`` whose plan is not in ``keep``.

        Used after a timed tune: losing candidates' jitted executables
        would otherwise live for the cache's lifetime. Returns #dropped.
        """
        fp = spec_fingerprint(spec)
        drop = [k for k in self._engines if k[0] == fp and k[1] not in keep]
        for k in drop:
            del self._engines[k]
            self._batched.pop(k, None)
        sdrop = [k for k in self._sharded
                 if k[0] == fp and k[1] not in keep]
        for k in sdrop:
            del self._sharded[k]
            self._sharded_batched.pop(k, None)
        return len(drop) + len(sdrop)

    def batched(self, spec: StencilSpec, plan: Plan,
                coefficients: Optional[Any] = None) -> Callable:
        """jit(vmap(engine)) over a leading batch axis, memoized."""
        k = (spec_fingerprint(spec), plan, _coeff_fp(coefficients))
        fn = self._batched.get(k)
        if fn is None:
            eng = self.engine(spec, plan, coefficients=coefficients)
            fn = jax.jit(jax.vmap(eng._fn))
            self._batched[k] = fn
        return fn

    # -- sharded executables -------------------------------------------------
    def _sharded_key(self, spec: StencilSpec, plan: Plan, mesh: Any,
                     grid_axes: Optional[Tuple[int, ...]]) -> ShardedKey:
        return (spec_fingerprint(spec), plan, mesh_desc(mesh),
                tuple(grid_axes) if grid_axes is not None else ())

    def sharded_engine(self, spec: StencilSpec, plan: Plan, mesh: Any,
                       grid_axes: Optional[Tuple[int, ...]] = None):
        """The (memoized) halo-exchange engine realizing ``plan`` on ``mesh``.

        ``mesh`` is a jax Mesh or an int/tuple of per-axis shard counts
        (see :func:`repro.distributed.halo.grid_mesh`).  Keyed by the
        canonical mesh geometry — two meshes with the same shard counts
        share one engine (they compile to the same program modulo device
        order).
        """
        from repro.distributed.halo import ShardedStencilEngine
        k = self._sharded_key(spec, plan, mesh, grid_axes)
        eng = self._sharded.get(k)
        if eng is None:
            self.stats.engine_builds += 1
            eng = ShardedStencilEngine(
                spec, mesh, backend=plan.backend, L=plan.L,
                star_fast_path=plan.star_fast_path,
                fuse_rows=plan.fuse_rows,
                temporal_steps=plan.temporal_steps,
                grid_axes=grid_axes)
            self._sharded[k] = eng
        else:
            self.stats.engine_hits += 1
        return eng

    def sharded_batched(self, spec: StencilSpec, plan: Plan, mesh: Any,
                        grid_axes: Optional[Tuple[int, ...]] = None
                        ) -> Callable:
        """jit(vmap(sharded engine)): every job in the batch is mesh-
        partitioned; the batch axis stays unsharded."""
        k = self._sharded_key(spec, plan, mesh, grid_axes)
        fn = self._sharded_batched.get(k)
        if fn is None:
            eng = self.sharded_engine(spec, plan, mesh, grid_axes=grid_axes)
            fn = jax.jit(jax.vmap(eng._fn))
            self._sharded_batched[k] = fn
        return fn

    # -- persistence ---------------------------------------------------------
    @staticmethod
    def _signature(path: Path) -> Optional[Tuple[int, int]]:
        """Cheap change detector for the persisted file: (mtime_ns, size)."""
        try:
            st = path.stat()
        except OSError:
            return None
        return (st.st_mtime_ns, st.st_size)

    def _read_plans(self, source: Path) -> Optional[Dict[str, Plan]]:
        """Decode the persisted file, skipping bad entries with a warning.

        Returns None when the whole file is unreadable / future-versioned
        (callers treat that as empty); keys are re-canonicalized so
        version-1 entries keep matching freshly-encoded lookups.
        """
        try:
            payload = json.loads(source.read_text())
            version = payload.get("version")
            raw = payload.get("plans", {})
            if not isinstance(raw, dict):
                raise TypeError("'plans' must be a dict")
        except (OSError, ValueError, KeyError, TypeError) as e:
            warnings.warn(f"tuner cache {source}: unreadable ({e}); ignoring",
                          RuntimeWarning, stacklevel=3)
            return None
        if version not in _READABLE_VERSIONS:
            warnings.warn(
                f"tuner cache {source}: format version {version!r} not in "
                f"{_READABLE_VERSIONS}; ignoring", RuntimeWarning,
                stacklevel=3)
            return None
        plans: Dict[str, Plan] = {}
        for k, d in raw.items():
            try:
                key = PlanKey.decode(k)
                plans[key.encode()] = Plan.from_dict(d)
            except (ValueError, KeyError, TypeError) as e:
                self.stats.skipped_entries += 1
                warnings.warn(
                    f"tuner cache {source}: skipping entry {k!r} ({e})",
                    RuntimeWarning, stacklevel=3)
        return plans

    def save(self, path: str | os.PathLike | None = None) -> Path:
        """Atomically write all plans as JSON; returns the path written.

        If the target changed on disk since this cache last read it, the
        on-disk entries are merged in first (in-memory plans win), so
        concurrent tuners converge instead of clobbering each other.
        """
        target = Path(path).expanduser() if path else self.path
        if target is None:
            raise ValueError("no persistence path set for this cache")
        target.parent.mkdir(parents=True, exist_ok=True)
        if target == self.path and target.exists():
            sig = self._signature(target)
            if sig is not None and sig != self._disk_sig:
                disk = self._read_plans(target) or {}
                merged = 0
                for k, p in disk.items():
                    if k not in self._plans:
                        self._plans[k] = p
                        merged += 1
                if merged:
                    self.stats.merges += 1
        payload = {"version": _FORMAT_VERSION,
                   "plans": {k: p.to_dict() for k, p in self._plans.items()}}
        fd, tmp = tempfile.mkstemp(dir=str(target.parent),
                                   prefix=target.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, target)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        if target == self.path:
            self._disk_sig = self._signature(target)
        self.stats.saves += 1
        return target

    def load(self, path: str | os.PathLike | None = None,
             missing_ok: bool = False) -> int:
        """Merge plans from a JSON file; returns the number loaded."""
        source = Path(path).expanduser() if path else self.path
        if source is None:
            raise ValueError("no persistence path set for this cache")
        if not source.exists():
            if missing_ok:
                return 0
            raise FileNotFoundError(source)
        sig = self._signature(source)
        plans = self._read_plans(source)
        if plans is None:
            return 0               # corrupt/unreadable cache: retune, don't crash
        self._plans.update(plans)
        if source == self.path:
            self._disk_sig = sig
        self.stats.loads += 1
        return len(plans)

    def clear(self, remove_file: bool = False) -> None:
        self._plans.clear()
        self._engines.clear()
        self._batched.clear()
        self._sharded.clear()
        self._sharded_batched.clear()
        self._disk_sig = None
        if remove_file and self.path is not None and self.path.exists():
            self.path.unlink()


# ---------------------------------------------------------------------------
# process-wide default cache
# ---------------------------------------------------------------------------

_default: Optional[PlanCache] = None


def default_cache() -> PlanCache:
    """The shared cache behind apply_stencil/tuned_apply.

    Persists iff ``REPRO_TUNER_CACHE`` names a file path at first use.
    """
    global _default
    if _default is None:
        _default = PlanCache(path=os.environ.get(CACHE_ENV_VAR) or None)
    return _default


def reset_default_cache() -> None:
    """Drop the process-wide cache (next default_cache() re-reads the env)."""
    global _default
    _default = None
