"""Execution plans and their cache keys.

A :class:`Plan` is everything ``StencilEngine`` needs beyond the spec
itself — the knobs SPIDER fixes at compile time.  A :class:`PlanKey`
identifies the tuning problem: the *stencil* (content fingerprint, not
object identity), the *input shape bucket* (next power of two per dim,
so nearby sizes share one plan while jit still specializes exact
shapes), the *dtype*, and the *device kind* (cpu/tpu/gpu — a plan tuned
on CPU must not be trusted on TPU).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import StencilSpec
from repro.core.transform import default_l


@dataclasses.dataclass(frozen=True)
class Plan:
    """Tuned engine configuration (hashable; JSON round-trippable)."""

    backend: str
    L: int
    fuse_rows: bool = False
    star_fast_path: bool = True

    def to_dict(self) -> dict:
        return {"backend": self.backend, "L": int(self.L),
                "fuse_rows": bool(self.fuse_rows),
                "star_fast_path": bool(self.star_fast_path)}

    @classmethod
    def from_dict(cls, d: dict) -> "Plan":
        return cls(backend=str(d["backend"]), L=int(d["L"]),
                   fuse_rows=bool(d.get("fuse_rows", False)),
                   star_fast_path=bool(d.get("star_fast_path", True)))

    @classmethod
    def default(cls, spec: StencilSpec, backend: str = "direct",
                L: int | None = None) -> "Plan":
        """The plan `StencilEngine(spec, backend)` would have used."""
        return cls(backend=backend,
                   L=L if L is not None else default_l(spec.radius))

    def describe(self) -> str:
        """Compact human-readable form, e.g. ``sptc/L8/fused``."""
        return f"{self.backend}/L{self.L}{'/fused' if self.fuse_rows else ''}"


def spec_fingerprint(spec: StencilSpec) -> str:
    """Content hash of a stencil spec (shape/ndim/radius/weights)."""
    h = hashlib.sha256()
    h.update(f"{spec.shape}|{spec.ndim}|{spec.radius}|".encode())
    h.update(np.ascontiguousarray(spec.weights, dtype=np.float64).tobytes())
    return h.hexdigest()[:16]


def shape_bucket(shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Round every dim up to the next power of two (min 1)."""
    return tuple(1 << max(0, int(np.ceil(np.log2(max(1, s))))) for s in shape)


def dtype_name(dtype: Any) -> str:
    return jnp.dtype(dtype).name


def device_kind() -> str:
    """Coarse device class the plan was tuned for: cpu | tpu | gpu."""
    return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Cache key for one tuning problem."""

    spec_fp: str
    bucket: Tuple[int, ...]
    dtype: str
    device: str

    def encode(self) -> str:
        """Stable string form used as the JSON dict key."""
        shape = "x".join(str(s) for s in self.bucket)
        return f"spec={self.spec_fp};shape={shape};dtype={self.dtype};dev={self.device}"

    @classmethod
    def decode(cls, s: str) -> "PlanKey":
        parts = dict(field.split("=", 1) for field in s.split(";"))
        bucket = tuple(int(v) for v in parts["shape"].split("x") if v)
        return cls(spec_fp=parts["spec"], bucket=bucket,
                   dtype=parts["dtype"], device=parts["dev"])


def plan_key(spec: StencilSpec, shape: Tuple[int, ...], dtype: Any,
             device: str | None = None) -> PlanKey:
    return PlanKey(spec_fp=spec_fingerprint(spec),
                   bucket=shape_bucket(tuple(shape)),
                   dtype=dtype_name(dtype),
                   device=device if device is not None else device_kind())
