"""Execution plans and their cache keys.

A :class:`Plan` is everything ``StencilEngine`` needs beyond the spec
itself — the knobs SPIDER fixes at compile time.  A :class:`PlanKey`
identifies the tuning problem: the *stencil* (content fingerprint, not
object identity), the *input shape bucket* (next power of two per dim,
so nearby sizes share one plan while jit still specializes exact
shapes), the *dtype*, the *device kind* (cpu/tpu/gpu — a plan tuned
on CPU must not be trusted on TPU), the *coefficient mode* (constant
weights vs a fingerprinted variable-coefficient field), the
*temporal block size*, and the *partition geometry* (single-device vs a
halo-exchange device mesh — see :func:`mesh_desc`).

Schema versioning (``PLAN_SCHEMA``): serialized plans and encoded keys
carry a version so caches written by future revisions are skipped, not
misread; fields added later default when absent and unknown fields are
ignored — PR-8's extended keys must not poison pre-existing
``REPRO_TUNER_CACHE`` files, nor vice versa.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import StencilSpec
from repro.core.transform import default_l

#: serialization schema for Plan dicts and PlanKey strings.
#:   1  (implicit) backend/L/fuse_rows/star_fast_path; unversioned keys
#:   2  + temporal_steps on Plan; versioned keys + coeff/steps fields
#:   3  + univ (backend-universe provenance) on PlanKey — plans tuned
#:      with the Pallas backends forced in (REPRO_TUNER_INCLUDE_PALLAS
#:      interpret-mode sweeps) key separately from plain-jnp tuning, so
#:      they can never poison a shared cache on CPU
#:   4  + mesh (partition geometry, e.g. "4x2") on PlanKey — a plan
#:      timed single-device must never be served to a halo-exchange-
#:      sharded run of the same spec/shape/dtype or vice versa (per-
#:      shard blocks see different shapes and communication costs);
#:      v1–v3 keys decode as mesh="1" (single device)
PLAN_SCHEMA = 4


@dataclasses.dataclass(frozen=True)
class Plan:
    """Tuned engine configuration (hashable; JSON round-trippable)."""

    backend: str
    L: int
    fuse_rows: bool = False
    star_fast_path: bool = True
    temporal_steps: int = 1

    def to_dict(self) -> dict:
        return {"schema": PLAN_SCHEMA,
                "backend": self.backend, "L": int(self.L),
                "fuse_rows": bool(self.fuse_rows),
                "star_fast_path": bool(self.star_fast_path),
                "temporal_steps": int(self.temporal_steps)}

    @classmethod
    def from_dict(cls, d: dict) -> "Plan":
        """Tolerant decode: unknown fields ignored, missing fields default.

        Raises ValueError on a future schema or a structurally unusable
        dict — the cache loader turns that into a warn-and-skip.
        """
        schema = int(d.get("schema", 1))
        if schema > PLAN_SCHEMA:
            raise ValueError(
                f"plan schema {schema} is newer than supported "
                f"{PLAN_SCHEMA}")
        return cls(backend=str(d["backend"]), L=int(d["L"]),
                   fuse_rows=bool(d.get("fuse_rows", False)),
                   star_fast_path=bool(d.get("star_fast_path", True)),
                   temporal_steps=int(d.get("temporal_steps", 1)))

    @classmethod
    def default(cls, spec: StencilSpec, backend: str = "direct",
                L: int | None = None, temporal_steps: int = 1) -> "Plan":
        """The plan `StencilEngine(spec, backend)` would have used."""
        return cls(backend=backend,
                   L=L if L is not None else default_l(spec.radius),
                   temporal_steps=temporal_steps)

    def describe(self) -> str:
        """Compact human-readable form, e.g. ``sptc/L8/fused/k4``."""
        out = f"{self.backend}/L{self.L}{'/fused' if self.fuse_rows else ''}"
        if self.temporal_steps != 1:
            out += f"/k{self.temporal_steps}"
        return out


def spec_fingerprint(spec: StencilSpec) -> str:
    """Content hash of a stencil spec (shape/ndim/radius/weights)."""
    h = hashlib.sha256()
    h.update(f"{spec.shape}|{spec.ndim}|{spec.radius}|".encode())
    h.update(np.ascontiguousarray(spec.weights, dtype=np.float64).tobytes())
    return h.hexdigest()[:16]


def coefficients_fingerprint(coefficients: Any) -> str:
    """Content hash of a variable-coefficient field (shape + values)."""
    c = np.ascontiguousarray(np.asarray(coefficients), dtype=np.float64)
    h = hashlib.sha256()
    h.update(f"{c.shape}|".encode())
    h.update(c.tobytes())
    return h.hexdigest()[:16]


def shape_bucket(shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Round every dim up to the next power of two (min 1)."""
    return tuple(1 << max(0, int(np.ceil(np.log2(max(1, s))))) for s in shape)


def dtype_name(dtype: Any) -> str:
    return jnp.dtype(dtype).name


def device_kind() -> str:
    """Coarse device class the plan was tuned for: cpu | tpu | gpu."""
    return jax.default_backend()


def mesh_desc(mesh: Any) -> str:
    """Canonical partition-geometry string for a plan key.

    ``"1"`` means single-device (no partitioning); a sharded run encodes
    its per-grid-axis shard counts, e.g. ``"8"`` (1-D mesh) or ``"4x2"``
    (2-D).  Accepts ``None``, an int, a tuple of shard counts, an
    already-encoded string, or anything mesh-shaped (``axis_names`` +
    ``shape`` attributes, i.e. ``jax.sharding.Mesh``).  Extent-1 axes
    carry no partitioning and are dropped — a mesh of all-1 extents IS
    single-device execution and canonicalizes to ``"1"``.
    """
    if mesh is None:
        return "1"
    if isinstance(mesh, str):
        parts = [p for p in mesh.split("x") if p]
    elif isinstance(mesh, int):
        parts = [mesh]
    elif isinstance(mesh, (tuple, list)):
        parts = list(mesh)
    elif hasattr(mesh, "axis_names") and hasattr(mesh, "shape"):
        parts = [mesh.shape[name] for name in mesh.axis_names]
    else:
        raise TypeError(
            f"mesh must be None, an int, a tuple of shard counts, an "
            f"encoded string, or a jax Mesh; got {type(mesh).__name__}")
    try:
        counts = [int(p) for p in parts]
    except (TypeError, ValueError):
        raise ValueError(f"unparseable mesh description {mesh!r}") from None
    if any(c < 1 for c in counts):
        raise ValueError(f"mesh shard counts must be >= 1, got {counts}")
    counts = [c for c in counts if c > 1]
    return "x".join(str(c) for c in counts) if counts else "1"


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Cache key for one tuning problem."""

    spec_fp: str
    bucket: Tuple[int, ...]
    dtype: str
    device: str
    coeff: str = "const"       # "const" | "var-<fingerprint>"
    steps: int = 1             # temporal block size the plan targets
    univ: str = "jnp"          # candidate universe: "jnp" | "jnp+pallas"
    mesh: str = "1"            # partition geometry: "1" | "8" | "4x2" | ...

    def encode(self) -> str:
        """Stable string form used as the JSON dict key (schema-prefixed)."""
        shape = "x".join(str(s) for s in self.bucket)
        return (f"v{PLAN_SCHEMA};spec={self.spec_fp};shape={shape};"
                f"dtype={self.dtype};dev={self.device};"
                f"coeff={self.coeff};steps={int(self.steps)};"
                f"univ={self.univ};mesh={self.mesh}")

    @classmethod
    def decode(cls, s: str) -> "PlanKey":
        """Decode v1 (unversioned) through v4 keys; tolerate unknown fields.

        Keys older than v3 carry no universe field and decode as
        ``univ="jnp"`` — pre-existing caches were tuned over the jnp
        universe unless the sweep env forced Pallas in, which is exactly
        the poisoning case v3 exists to fence off.  Keys older than v4
        carry no mesh field and decode as ``mesh="1"`` — everything
        before the halo-exchange engine was tuned single-device.

        Raises ValueError on a future-versioned or structurally corrupt
        key — the cache loader turns that into a warn-and-skip.
        """
        fields = s.split(";")
        version = 1
        if fields and "=" not in fields[0]:
            tag = fields[0]
            if not tag.startswith("v") or not tag[1:].isdigit():
                raise ValueError(f"unrecognized plan-key prefix {tag!r}")
            version = int(tag[1:])
            if version > PLAN_SCHEMA:
                raise ValueError(
                    f"plan-key schema {version} is newer than supported "
                    f"{PLAN_SCHEMA}")
            fields = fields[1:]
        parts = dict(field.split("=", 1) for field in fields if field)
        bucket = tuple(int(v) for v in parts["shape"].split("x") if v)
        return cls(spec_fp=parts["spec"], bucket=bucket,
                   dtype=parts["dtype"], device=parts["dev"],
                   coeff=parts.get("coeff", "const"),
                   steps=int(parts.get("steps", 1)),
                   univ=parts.get("univ", "jnp"),
                   mesh=parts.get("mesh", "1"))


def plan_key(spec: StencilSpec, shape: Tuple[int, ...], dtype: Any,
             device: str | None = None, *,
             coefficients: Optional[Any] = None,
             temporal_steps: int = 1, mesh: Any = None) -> PlanKey:
    from repro.kernels.dispatch import backend_universe
    coeff = ("const" if coefficients is None
             else f"var-{coefficients_fingerprint(coefficients)}")
    dev = device if device is not None else device_kind()
    return PlanKey(spec_fp=spec_fingerprint(spec),
                   bucket=shape_bucket(tuple(shape)),
                   dtype=dtype_name(dtype),
                   device=dev, coeff=coeff, steps=temporal_steps,
                   univ=backend_universe(dev), mesh=mesh_desc(mesh))
