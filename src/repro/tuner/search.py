"""Autotuner: candidate enumeration, timing, and a static cost model.

Two selection modes:

  ``time``  build each candidate engine, run warmup (absorbing the jit
            compile — SPIDER's "slight compile-time cost"), then take the
            median of ``iters`` wall-clock runs.  Ground truth, used by
            benchmarks and long-lived serving processes.
  ``cost``  rank candidates by a static per-output-point model in the
            spirit of ``core/analysis.py`` (Table 1): MACs charged at the
            executing unit's relative throughput plus a per-dispatch
            overhead.  Deterministic and build-free — used when timing is
            disabled (tests, cold imports, sizing dry-runs).

Candidates are the applicable backends (``kernels.dispatch``) crossed
with a small even-``L`` grid (paper §3.2.2 fixes L = 2r+2 for exact 50%
band density; larger L trades density for fewer, bigger GEMM tiles) and,
for 2-D non-star stencils on the matrix backends, the fused-rows variant.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import StencilSpec
from repro.core.transform import decompose_rows, default_l
from repro.tuner.plan import Plan

# Cost-model constants (relative, dimensionless). The matrix units (MXU /
# SpTC) retire MACs ~an order of magnitude faster than scalar/vector FMA;
# every separate 1-D application (gather + dispatch) carries fixed overhead.
MATRIX_UNIT_SPEEDUP = 8.0
DISPATCH_OVERHEAD = 0.25


def l_candidates(radius: int, max_candidates: int = 3) -> List[int]:
    """Small even-L grid: the paper's 2r+2 plus MXU-friendlier roundings."""
    base = default_l(radius)
    cands = {base, -(-base // 8) * 8}
    if 16 >= base:
        cands.add(16)
    return sorted(cands)[:max_candidates]


def candidate_plans(spec: StencilSpec, device: str | None = None, *,
                    temporal_steps: int = 1,
                    variable_coefficients: bool = False) -> List[Plan]:
    """All plans worth trying for ``spec`` on ``device``.

    ``temporal_steps`` stamps every candidate with the requested temporal
    block; ``variable_coefficients`` restricts to the backends/modes the
    variable-coefficient emitter supports (jnp backends, no row fusion,
    no temporal blocking — see ``transform.lower_spec``).
    """
    from repro.kernels.dispatch import applicable_backends
    plans: List[Plan] = []
    star = spec.shape == "star"
    k = temporal_steps
    for backend in applicable_backends(spec, device):
        if variable_coefficients and backend not in ("direct", "gemm",
                                                     "sptc"):
            continue
        if backend in ("direct", "pallas_direct"):
            plans.append(Plan(backend=backend, L=default_l(spec.radius),
                              temporal_steps=k))
            continue
        for L in l_candidates(spec.radius):
            plans.append(Plan(backend=backend, L=L, temporal_steps=k))
            if (spec.ndim == 2 and not star and backend in ("gemm", "sptc")
                    and not variable_coefficients):
                plans.append(Plan(backend=backend, L=L, fuse_rows=True,
                                  temporal_steps=k))
    return plans


def _n_applications(spec: StencilSpec, plan: Plan) -> int:
    if spec.ndim == 1:
        return 1
    if plan.star_fast_path and spec.shape == "star":
        return spec.ndim
    return len(decompose_rows(spec))


def static_cost(spec: StencilSpec, plan: Plan) -> float:
    """Relative cost per output point (lower is better).

    direct      taps MACs on the scalar/vector unit, one dispatch per tap.
    gemm-like   2L MACs per point per 1-D application (dense band, §2.3's
                >=2x waste) on the matrix unit.
    sptc-like   L MACs per point per application (SpTC executes K/2, §3.2.3)
                on the matrix unit.
    fuse_rows   same MACs, one dispatch (§Perf D single stacked GEMM).
    temporal    a k-step block costs k× one step (per-step work is
                unchanged — the §3.3 zero-overhead profile holds per step).
    """
    napps = _n_applications(spec, plan)
    if plan.backend == "direct":
        macs, tput, dispatches = float(spec.taps), 1.0, spec.taps
    elif plan.backend == "pallas_direct":
        # same MACs as direct, fused into one kernel with in-VMEM reuse
        macs, tput, dispatches = float(spec.taps), 2.0, 1
    elif plan.backend in ("gemm", "pallas_mxu"):
        macs, tput, dispatches = float(napps * 2 * plan.L), MATRIX_UNIT_SPEEDUP, napps
    elif plan.backend in ("sptc", "pallas_sptc"):
        macs, tput, dispatches = float(napps * plan.L), MATRIX_UNIT_SPEEDUP, napps
    else:
        raise ValueError(f"unknown backend {plan.backend}")
    if plan.fuse_rows:
        dispatches = 1
    return plan.temporal_steps * (macs / tput
                                  + DISPATCH_OVERHEAD * dispatches)


@dataclasses.dataclass(frozen=True)
class Candidate:
    plan: Plan
    score: float | None        # seconds (time mode) or model cost (cost mode)
    error: str | None = None


@dataclasses.dataclass(frozen=True)
class TuneResult:
    plan: Plan
    mode: str
    candidates: Tuple[Candidate, ...]

    @property
    def best_score(self) -> float:
        return min(c.score for c in self.candidates
                   if c.error is None and c.plan == self.plan)


def _default_engine_factory(spec: StencilSpec, plan: Plan,
                            coefficients: Any = None) -> "StencilEngine":
    from repro.core.engine import StencilEngine
    return StencilEngine(spec, backend=plan.backend, L=plan.L,
                         star_fast_path=plan.star_fast_path,
                         fuse_rows=plan.fuse_rows,
                         temporal_steps=plan.temporal_steps,
                         coefficients=coefficients)


def measure(fn: Callable, x: jnp.ndarray, warmup: int = 1,
            iters: int = 3) -> float:
    """Median wall-clock seconds per call; warmup absorbs the jit compile."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(x))
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def autotune(spec: StencilSpec, shape: Sequence[int],
             dtype: Any = jnp.float32, *,
             mode: str = "time",
             engine_factory: Callable | None = None,
             temporal_steps: int = 1, coefficients: Any = None,
             warmup: int = 1, iters: int = 3, seed: int = 0) -> TuneResult:
    """Pick the best Plan for (spec, input shape, dtype) on this device.

    ``shape`` is the halo-inclusive input shape, exactly what the engine
    will be called with (for a k-step temporal block that means the k·r
    halo; for variable coefficients it must match the field's fixed
    shape).  Candidates that fail to build or run are skipped (recorded
    with their error).  If every timed candidate fails — or ``mode ==
    "cost"`` — selection falls back to the static cost model.
    """
    if mode not in ("time", "cost"):
        raise ValueError(f"mode must be 'time' or 'cost', got {mode!r}")
    plans = candidate_plans(spec, temporal_steps=temporal_steps,
                            variable_coefficients=coefficients is not None)
    if not plans:
        raise RuntimeError(f"no applicable backends for {spec.name}")
    factory = engine_factory or _default_engine_factory

    if mode == "cost":
        cands = tuple(Candidate(p, static_cost(spec, p)) for p in plans)
        best = min(cands, key=lambda c: c.score)
        return TuneResult(plan=best.plan, mode="cost", candidates=cands)

    x = jnp.asarray(np.random.default_rng(seed).normal(size=tuple(shape)),
                    dtype=dtype)
    cands: List[Candidate] = []
    for p in plans:
        try:
            eng = factory(spec, p, coefficients=coefficients)
            t = measure(eng, x, warmup=warmup, iters=iters)
            cands.append(Candidate(p, t))
        except Exception as e:  # noqa: BLE001 — any backend failure skips it
            cands.append(Candidate(p, None, error=f"{type(e).__name__}: {e}"))
    timed = [c for c in cands if c.error is None]
    if not timed:
        fallback = autotune(spec, shape, dtype, mode="cost",
                            temporal_steps=temporal_steps,
                            coefficients=coefficients)
        return TuneResult(plan=fallback.plan, mode="cost",
                          candidates=tuple(cands) + fallback.candidates)
    best = min(timed, key=lambda c: c.score)
    return TuneResult(plan=best.plan, mode="time", candidates=tuple(cands))
