"""repro.vet — ahead-of-time verifier for the SPIDER reproduction.

Three analyzers over one findings/baseline/CLI spine:

* :mod:`repro.vet.invariants` — transform-pipeline algebra (bandedness,
  involution, 2:4 pattern, metadata, gather ranges) on pure NumPy;
* :mod:`repro.vet.lowering` — lowered-HLO purity of the tuned engines
  (dot counts, hot-path gather/copy budget, sparse-vs-dense parity,
  retrace count) certifying the paper's zero-runtime-overhead claim;
* :mod:`repro.vet.code` — AST lint for serving/tuner hot paths
  (per-request jit, host syncs, lock discipline, nondeterministic keys).

Run with ``python -m repro.vet [paths]``.
"""
from repro.vet.baseline import Baseline, BaselineEntry
from repro.vet.config import VetConfig, load_config
from repro.vet.findings import Finding

__all__ = ["Baseline", "BaselineEntry", "Finding", "VetConfig",
           "load_config"]
