import sys

from repro.vet.cli import main

if __name__ == "__main__":
    sys.exit(main())
