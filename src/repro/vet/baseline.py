"""Baseline: the checked-in set of findings a tree is *allowed* to have.

Each entry is a documented, reviewed exception — a rule match that was
inspected and judged intentional (e.g. the serving driver's deliberate
``block_until_ready`` that anchors its latency metric).  Matching is by
:meth:`Finding.key` (rule + path + symbol), deliberately line-free so
unrelated edits don't invalidate entries.

Format (JSON, sorted, diff-friendly)::

    {"version": 1,
     "entries": [{"rule": "...", "path": "...", "symbol": "...",
                  "reason": "why this is accepted"}]}

``python -m repro.vet --write-baseline`` regenerates entries from the
current findings (preserving reasons of kept entries); unused entries
are reported so the baseline can only shrink silently, never grow.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Tuple

from repro.vet.findings import Finding

_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    symbol: str
    reason: str = ""

    def key(self) -> str:
        return f"{self.rule}::{self.path}::{self.symbol}"


class Baseline:
    def __init__(self, entries: List[BaselineEntry] | None = None):
        self.entries = list(entries or [])

    def __len__(self) -> int:
        return len(self.entries)

    def split(self, findings: List[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[BaselineEntry]]:
        """(new findings, suppressed findings, unused baseline entries)."""
        by_key: Dict[str, BaselineEntry] = {e.key(): e for e in self.entries}
        new: List[Finding] = []
        suppressed: List[Finding] = []
        used = set()
        for f in findings:
            if f.key() in by_key:
                suppressed.append(f)
                used.add(f.key())
            else:
                new.append(f)
        unused = [e for e in self.entries if e.key() not in used]
        return new, suppressed, unused

    @classmethod
    def load(cls, path: Path, missing_ok: bool = True) -> "Baseline":
        if not Path(path).exists():
            if missing_ok:
                return cls()
            raise FileNotFoundError(path)
        payload = json.loads(Path(path).read_text())
        if payload.get("version") != _FORMAT_VERSION:
            return cls()
        entries = [BaselineEntry(rule=str(e["rule"]), path=str(e["path"]),
                                 symbol=str(e.get("symbol", "")),
                                 reason=str(e.get("reason", "")))
                   for e in payload.get("entries", [])]
        return cls(entries)

    def save(self, path: Path) -> None:
        payload = {
            "version": _FORMAT_VERSION,
            "entries": [dataclasses.asdict(e) for e in sorted(
                self.entries, key=lambda e: e.key())],
        }
        Path(path).write_text(json.dumps(payload, indent=1, sort_keys=True)
                              + "\n")

    @classmethod
    def from_findings(cls, findings: List[Finding],
                      previous: "Baseline | None" = None) -> "Baseline":
        """Entries for every finding, keeping reasons from ``previous``."""
        reasons = {e.key(): e.reason for e in (previous.entries
                                               if previous else [])}
        seen = set()
        entries = []
        for f in findings:
            if f.key() in seen:
                continue
            seen.add(f.key())
            entries.append(BaselineEntry(
                rule=f.rule, path=f.path, symbol=f.symbol,
                reason=reasons.get(f.key(), "TODO: document why accepted")))
        return cls(entries)
