"""``python -m repro.vet`` — run the analyzers, print findings, gate CI.

    python -m repro.vet src/repro                 # all three analyzers
    python -m repro.vet --analyzers code src      # subset
    python -m repro.vet src/repro --json          # machine-readable
    python -m repro.vet src/repro --write-baseline

Exit status: 0 when no *error* finding survives the baseline, 1 when at
least one does, 2 on usage errors.  Warnings and infos never fail the
run; suppressed findings and unused baseline entries are reported so the
baseline stays honest.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

from repro.vet.baseline import Baseline
from repro.vet.config import VetConfig, load_config
from repro.vet.findings import Finding, counts_by_severity

ANALYZERS = ("invariants", "lowering", "code")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.vet",
        description="Ahead-of-time verifier: SPIDER transform invariants, "
                    "lowered-HLO purity, and hot-path/concurrency lint.")
    p.add_argument("paths", nargs="*", default=[],
                   help="files/directories for the code analyzer "
                        "(default: src/repro under the config root)")
    p.add_argument("--analyzers", default=",".join(ANALYZERS),
                   help="comma-separated subset of: " + ", ".join(ANALYZERS))
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit a JSON report instead of text")
    p.add_argument("--baseline", default=None,
                   help="baseline file (default: [tool.repro-vet].baseline)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline entirely")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline from current findings "
                        "(preserves reasons of kept entries) and exit 0")
    p.add_argument("--root", default=None,
                   help="repo root for relative paths (default: pyproject "
                        "directory)")
    return p


def run_analyzers(cfg: VetConfig, which: List[str], paths: List[Path]
                  ) -> tuple[List[Finding], Optional[Dict[str, dict]]]:
    findings: List[Finding] = []
    verdict: Optional[Dict[str, dict]] = None
    if "invariants" in which:
        from repro.vet import invariants
        findings += invariants.run(cfg)
    if "lowering" in which:
        from repro.vet import lowering
        fs, verdict = lowering.run(cfg)
        findings += fs
    if "code" in which:
        from repro.vet import code
        findings += code.run(cfg, paths)
    return findings, verdict


def _print_text(new: List[Finding], suppressed: List[Finding],
                unused, verdict: Optional[Dict[str, dict]],
                out=None) -> None:
    out = out if out is not None else sys.stdout
    for f in new:
        print(f.format(), file=out)
    if verdict:
        print("zero-overhead verdict:", file=out)
        for kernel in sorted(verdict):
            v = verdict[kernel]
            status = "certified" if v.get("certified") else "NOT certified"
            traces = v.get("traces")
            extra = f", traces={traces}" if traces is not None else ""
            print(f"  {kernel}: {status}{extra}", file=out)
            for probe in sorted(v.get("probes", {})):
                counts = v["probes"][probe]
                ops = " ".join(f"{k}={counts[k]}" for k in sorted(counts))
                print(f"    {probe}: {ops}", file=out)
    if suppressed:
        print(f"{len(suppressed)} finding(s) suppressed by baseline",
              file=out)
    for e in unused:
        print(f"warning: unused baseline entry {e.key()!r} — remove it",
              file=out)
    counts = counts_by_severity(new)
    print(f"vet: {counts['error']} error(s), {counts['warning']} "
          f"warning(s), {counts['info']} info(s)", file=out)


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    which = [a.strip() for a in args.analyzers.split(",") if a.strip()]
    bad = [a for a in which if a not in ANALYZERS]
    if bad:
        print(f"repro.vet: unknown analyzer(s): {', '.join(bad)} "
              f"(choose from {', '.join(ANALYZERS)})", file=sys.stderr)
        return 2

    root = Path(args.root) if args.root else None
    cfg = load_config(root=root or Path.cwd())
    if root is not None:
        cfg.root = root
    if args.baseline:
        cfg.baseline = args.baseline

    paths = [Path(p) for p in args.paths]
    if not paths and "code" in which:
        default = cfg.root / "src" / "repro"
        if default.exists():
            paths = [default]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print("repro.vet: no such path(s): "
              + ", ".join(str(p) for p in missing), file=sys.stderr)
        return 2

    findings, verdict = run_analyzers(cfg, which, paths)

    bl_path = cfg.baseline_path()
    if args.write_baseline:
        previous = Baseline.load(bl_path)
        Baseline.from_findings(findings, previous).save(bl_path)
        print(f"repro.vet: wrote {len(findings)} finding(s) to {bl_path}")
        return 0

    baseline = Baseline() if args.no_baseline else Baseline.load(bl_path)
    new, suppressed, unused = baseline.split(findings)

    if args.as_json:
        report = {
            "findings": [f.to_dict() for f in new],
            "suppressed": [f.to_dict() for f in suppressed],
            "unused_baseline": [e.key() for e in unused],
            "counts": counts_by_severity(new),
        }
        if verdict is not None:
            report["verdict"] = verdict
        print(json.dumps(report, indent=1, sort_keys=True))
    else:
        _print_text(new, suppressed, unused, verdict)

    return 1 if any(f.severity == "error" for f in new) else 0


if __name__ == "__main__":          # pragma: no cover
    sys.exit(main())
