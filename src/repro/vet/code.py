"""Code analyzer: walk Python sources, apply every AST rule.

Paths are reported repo-relative (relative to the config root) with
forward slashes, so findings and baseline entries are stable across
checkouts.  Unparseable files produce a ``code-parse`` error finding
rather than crashing the run — a vet tool that dies on the tree it vets
is useless in CI.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, Iterator, List, Optional

from repro.vet.config import VetConfig
from repro.vet.findings import Finding
from repro.vet.rules import ALL_RULES, Rule, RuleContext

SKIP_DIRS = {"__pycache__", ".git", ".venv", "build", "dist"}


def iter_py_files(paths: Iterable[Path]) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in SKIP_DIRS for part in f.parts):
                    yield f


def rel_path(path: Path, root: Path) -> str:
    try:
        return Path(path).resolve().relative_to(
            Path(root).resolve()).as_posix()
    except ValueError:
        return Path(path).as_posix()


def check_file(cfg: VetConfig, path: Path,
               rules: Optional[List[Rule]] = None) -> List[Finding]:
    rp = rel_path(path, cfg.root)
    try:
        tree = ast.parse(Path(path).read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(rule="code-parse", severity="error", path=rp,
                        line=e.lineno or 0, symbol="<module>",
                        message=f"syntax error: {e.msg}")]
    ctx = RuleContext(cfg=cfg, path=rp, tree=tree)
    out: List[Finding] = []
    for rule in (rules if rules is not None else ALL_RULES):
        out += rule.check(ctx)
    return out


def run(cfg: VetConfig, paths: Iterable[Path],
        rules: Optional[List[Rule]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings += check_file(cfg, f, rules=rules)
    return findings
