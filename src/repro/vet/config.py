"""``[tool.repro-vet]`` configuration (pyproject.toml).

Everything has a working default — a bare ``python -m repro.vet`` on a
checkout needs no config at all.  The pyproject block can:

  * move the baseline file (``baseline = ".vet-baseline.json"``);
  * re-rank any rule's severity (``[tool.repro-vet.severity]``,
    ``rule-id = "error" | "warning" | "info" | "off"``);
  * change which modules count as serving/tuner *hot paths* for the
    code analyzer (``hot_path_modules``);
  * tighten or relax the lowering analyzer's per-backend op budgets
    (``[tool.repro-vet.lowering.budgets.<backend>]``, opcode -> count
    per 1-D application).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional

try:                                 # Python 3.11+
    import tomllib
except ModuleNotFoundError:          # pragma: no cover — 3.10 fallback
    import tomli as tomllib

#: default severity per rule id (overridable per-project)
DEFAULT_SEVERITY: Dict[str, str] = {
    # invariant analyzer
    "invariant-banded": "error",
    "invariant-involution": "error",
    "invariant-24": "error",
    "invariant-meta": "error",
    "invariant-gather-range": "error",
    "invariant-roundtrip": "error",
    "invariant-plan-stages": "error",
    "invariant-shared-pattern": "error",
    # lowering analyzer
    "lowering-dot-count": "error",
    "lowering-hot-gather": "error",
    "lowering-hot-overhead": "error",
    "lowering-sparse-parity": "error",
    "lowering-retrace": "error",
    # fused-Pallas analyzer (jaxpr-level; interpret-mode safe)
    "pallas-fused-program": "error",
    "pallas-fused-gather": "error",
    "pallas-fused-overhead": "error",
    # sharded halo-exchange analyzer (needs >= 2 devices to probe)
    "sharded-collective-budget": "error",
    "sharded-all-gather": "error",
    # code analyzer
    "code-jit-per-call": "error",
    "code-host-sync": "warning",
    "code-lock-discipline": "error",
    "code-locked-suffix": "error",
    "code-nondet-key": "error",
}

#: per-backend op budget for the matmul hot path, per 1-D application.
#: the window (im2col) gather is intrinsic; everything beyond it is the
#: runtime overhead SPIDER's §3.3 row-swap contract forbids.
DEFAULT_HOT_BUDGET: Dict[str, Dict[str, int]] = {
    "gemm": {"gather": 1, "dynamic-slice": 0},
    "sptc": {"gather": 1, "dynamic-slice": 0},
    # the fused Pallas kernel DMAs its own windows: at most 1 gather per
    # application may remain outside the fused program, zero dynamic slices
    "pallas_sptc": {"gather": 1, "dynamic-slice": 0},
}


@dataclasses.dataclass
class VetConfig:
    baseline: str = ".vet-baseline.json"
    severity: Dict[str, str] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_SEVERITY))
    hot_path_modules: List[str] = dataclasses.field(
        default_factory=lambda: ["serving", "tuner"])
    hot_path_functions: List[str] = dataclasses.field(
        default_factory=lambda: ["submit", "_run_batch", "_execute",
                                 "_worker", "map", "drain", "__call__",
                                 "tuned_apply", "tuned_apply_batched"])
    lowering_budgets: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=lambda: {b: dict(v)
                                 for b, v in DEFAULT_HOT_BUDGET.items()})
    lowering_backends: List[str] = dataclasses.field(
        default_factory=lambda: ["gemm", "sptc"])
    invariant_radii: List[int] = dataclasses.field(
        default_factory=lambda: [1, 2, 3, 4])
    root: Path = dataclasses.field(default_factory=Path.cwd)

    def severity_of(self, rule: str, default: str = "error") -> str:
        return self.severity.get(rule, DEFAULT_SEVERITY.get(rule, default))

    def baseline_path(self) -> Path:
        p = Path(self.baseline)
        return p if p.is_absolute() else self.root / p


def load_config(pyproject: Optional[Path] = None,
                root: Optional[Path] = None) -> VetConfig:
    """Config from a pyproject.toml's ``[tool.repro-vet]`` block.

    Missing file / missing block -> all defaults.  ``root`` anchors the
    relative baseline path (defaults to the pyproject's directory).
    """
    cfg = VetConfig()
    if pyproject is None:
        pyproject = _find_pyproject(root or Path.cwd())
    if pyproject is None or not pyproject.exists():
        if root is not None:
            cfg.root = Path(root)
        return cfg
    cfg.root = Path(root) if root is not None else pyproject.parent
    with open(pyproject, "rb") as f:
        data = tomllib.load(f)
    block = data.get("tool", {}).get("repro-vet", {})
    if not isinstance(block, dict):
        return cfg
    if isinstance(block.get("baseline"), str):
        cfg.baseline = block["baseline"]
    if isinstance(block.get("hot_path_modules"), list):
        cfg.hot_path_modules = [str(m) for m in block["hot_path_modules"]]
    if isinstance(block.get("hot_path_functions"), list):
        cfg.hot_path_functions = [str(m) for m in block["hot_path_functions"]]
    if isinstance(block.get("invariant_radii"), list):
        cfg.invariant_radii = [int(r) for r in block["invariant_radii"]]
    sev = block.get("severity", {})
    if isinstance(sev, dict):
        for rule, s in sev.items():
            cfg.severity[str(rule)] = str(s)
    lowering = block.get("lowering", {})
    if isinstance(lowering, dict):
        if isinstance(lowering.get("backends"), list):
            cfg.lowering_backends = [str(b) for b in lowering["backends"]]
        budgets = lowering.get("budgets", {})
        if isinstance(budgets, dict):
            for backend, ops in budgets.items():
                if isinstance(ops, dict):
                    cfg.lowering_budgets.setdefault(str(backend), {}).update(
                        {str(op): int(n) for op, n in ops.items()})
    return cfg


def _find_pyproject(start: Path) -> Optional[Path]:
    for d in [start] + list(start.parents):
        candidate = d / "pyproject.toml"
        if candidate.exists():
            return candidate
    return None
