"""Structured findings shared by every ``repro.vet`` analyzer.

A :class:`Finding` is one verifiable claim about the tree: a rule id
(``invariant-24``, ``lowering-hot-gather``, ``code-host-sync``, ...), a
severity, a location, and a message.  Findings are what the CLI prints
(text or JSON), what the baseline suppresses, and what decides the exit
code — ``error`` findings outside the baseline fail the run.

The ``symbol`` field is the *stable* part of the location (a qualified
function name, a backend name, a sweep point) so baseline entries keep
matching across line-number drift.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer result."""

    rule: str                       # stable rule id, e.g. "code-host-sync"
    severity: str                   # "error" | "warning" | "info"
    path: str                       # file the finding is about ("-" if n/a)
    line: int                       # 1-based; 0 when not line-anchored
    symbol: str                     # enclosing symbol / backend / sweep point
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def key(self) -> str:
        """Line-independent identity used for baseline matching."""
        return f"{self.rule}::{self.path}::{self.symbol}"

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.severity}: [{self.rule}] {self.symbol}: {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(rule=str(d["rule"]), severity=str(d["severity"]),
                   path=str(d["path"]), line=int(d.get("line", 0)),
                   symbol=str(d.get("symbol", "")), message=str(d["message"]))


def with_severity(finding: Finding, severity: str) -> Finding:
    """The same finding at a (config-overridden) severity."""
    if severity == finding.severity:
        return finding
    return dataclasses.replace(finding, severity=severity)


def worst_severity(findings: List[Finding]) -> Optional[str]:
    for sev in SEVERITIES:          # ordered worst-first
        if any(f.severity == sev for f in findings):
            return sev
    return None


def counts_by_severity(findings: List[Finding]) -> dict:
    out = {sev: 0 for sev in SEVERITIES}
    for f in findings:
        out[f.severity] += 1
    return out
