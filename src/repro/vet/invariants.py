"""Ahead-of-time verification of the SPIDER transform pipeline (pure NumPy).

SPIDER's correctness argument is *static*: for every stencil the repo can
execute, the banded kernel matrix, the strided-swap permutation, and the
2:4 encoding must satisfy checkable algebraic invariants **before any
kernel runs** (paper §3.2).  This analyzer re-derives each invariant on
kernel matrices only — no jit, no kernel execution — over the paper
benchmark suite's row kernels × a radius/L sweep:

  invariant-banded        K[i, j] == w[j-i] inside the band, 0 outside
  invariant-involution    strided_swap_perm is a self-inverse permutation
  invariant-24            the swapped matrix is genuinely 2:4 sparse
  invariant-meta          Sparse24.meta in [0,4), strictly increasing per
                          segment pair, consistent with meta_bits packing
  invariant-gather-range  gather_indices land inside [0, K) and in the
                          right segment
  invariant-roundtrip     decode(encode(Kp)) == Kp exactly

On top of the per-matrix algebra, the analyzer lowers probe specs into
the explicit :class:`~repro.core.ir.LoweredPlan` IR and re-checks the
pipeline *as a whole* (still no jit, pure table inspection):

  invariant-plan-stages    every plan carries the canonical stage
                           subsequence for its backend family, passes
                           structural validation, and keeps its tables
                           mutually consistent (const, variable-
                           coefficient, and temporal-blocked probes)
  invariant-shared-pattern variable-coefficient plans share ONE 2:4
                           pattern / meta-bits / gather schedule across
                           all row operands — the property that lets the
                           swap permutation be computed once

Every check doubles as a *failure-injection* point for tests: pass a
corrupted matrix / permutation / Sparse24 and the analyzer must produce
the corresponding finding.
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from repro.core.ir import (MATRIX_BACKENDS, SPARSE_BACKENDS, STAGE_ORDER,
                           LoweredPlan)
from repro.core.sparsify import (Sparse24, apply_col_perm, decode_24,
                                 encode_24, is_24_sparse, strided_swap_perm)
from repro.core.stencil import paper_suite, star_mask
from repro.core.transform import (decompose_rows, default_l, kernel_matrix,
                                  lower_spec)
from repro.vet.config import VetConfig
from repro.vet.findings import Finding

_PATH = "src/repro/core/sparsify.py"
_IR_PATH = "src/repro/core/ir.py"


def _finding(cfg: VetConfig, rule: str, symbol: str, message: str,
             path: str = _PATH) -> Finding:
    return Finding(rule=rule, severity=cfg.severity_of(rule), path=path,
                   line=0, symbol=symbol, message=message)


def check_kernel_matrix(cfg: VetConfig, K: np.ndarray, w: np.ndarray,
                        L: int, symbol: str) -> List[Finding]:
    """Bandedness: row i holds w at columns [i, i+2r], zero elsewhere."""
    out: List[Finding] = []
    taps = w.shape[0]
    if K.shape != (L, 2 * L):
        out.append(_finding(cfg, "invariant-banded", symbol,
                            f"kernel matrix shape {K.shape} != ({L}, {2 * L})"))
        return out
    for i in range(L):
        band = K[i, i:i + taps]
        if not np.array_equal(band, w):
            out.append(_finding(cfg, "invariant-banded", symbol,
                                f"row {i} band does not equal the stencil "
                                f"kernel"))
            break
    mask = np.ones_like(K, dtype=bool)
    for i in range(L):
        mask[i, i:i + taps] = False
    if np.any(K[mask] != 0):
        out.append(_finding(cfg, "invariant-banded", symbol,
                            "non-zero entries outside the band"))
    return out


def check_involution(cfg: VetConfig, perm: np.ndarray,
                     symbol: str) -> List[Finding]:
    """The strided swap must be a self-inverse permutation of 2L columns."""
    out: List[Finding] = []
    n = perm.shape[0]
    if sorted(perm.tolist()) != list(range(n)):
        out.append(_finding(cfg, "invariant-involution", symbol,
                            "strided_swap_perm is not a permutation"))
        return out
    if not np.array_equal(perm[perm], np.arange(n)):
        out.append(_finding(cfg, "invariant-involution", symbol,
                            "strided_swap_perm is not an involution "
                            "(perm[perm] != identity)"))
    return out


def check_24_pattern(cfg: VetConfig, Kp: np.ndarray,
                     symbol: str) -> List[Finding]:
    """Post-swap matrix must hold <= 2 non-zeros per aligned 4-segment."""
    if Kp.shape[1] % 4 != 0:
        return [_finding(cfg, "invariant-24", symbol,
                         f"width {Kp.shape[1]} not a multiple of 4")]
    if not is_24_sparse(Kp):
        seg = (Kp.reshape(Kp.shape[0], -1, 4) != 0).sum(axis=-1)
        bad = np.argwhere(seg > 2)[0]
        return [_finding(cfg, "invariant-24", symbol,
                         f"strided swap failed: row {bad[0]} segment "
                         f"{bad[1]} holds {seg[bad[0], bad[1]]} non-zeros")]
    return []


def check_sparse24(cfg: VetConfig, sp: Sparse24, Kp: np.ndarray | None,
                   symbol: str) -> List[Finding]:
    """Metadata domain/order, gather ranges, bit packing, and round-trip."""
    out: List[Finding] = []
    meta = np.asarray(sp.meta)
    if meta.size and (meta.min() < 0 or meta.max() > 3):
        out.append(_finding(cfg, "invariant-meta", symbol,
                            f"meta outside [0, 4): min={meta.min()} "
                            f"max={meta.max()}"))
    pairs = meta.reshape(meta.shape[0], -1, 2)
    if np.any(pairs[:, :, 0] >= pairs[:, :, 1]):
        bad = np.argwhere(pairs[:, :, 0] >= pairs[:, :, 1])[0]
        out.append(_finding(cfg, "invariant-meta", symbol,
                            f"meta not strictly increasing in row {bad[0]} "
                            f"segment {bad[1]} (LSB-first order violated)"))
    words = sp.meta_bits()
    for f in range(min(16, meta.shape[1])):
        unpacked = (words[:, f // 16] >> (2 * (f % 16))) & 0x3
        if not np.array_equal(unpacked, meta[:, f].astype(np.uint32) & 0x3):
            out.append(_finding(cfg, "invariant-meta", symbol,
                                f"meta_bits field {f} disagrees with meta "
                                "(LSB-first packing broken)"))
            break
    idx = sp.gather_indices()
    if idx.size and (idx.min() < 0 or idx.max() >= sp.k):
        out.append(_finding(cfg, "invariant-gather-range", symbol,
                            f"gather index out of range [0, {sp.k}): "
                            f"min={idx.min()} max={idx.max()}"))
    else:
        seg = np.arange(idx.shape[1]) // 2          # segment of each slot
        if np.any(idx // 4 != seg[None, :]):
            out.append(_finding(cfg, "invariant-gather-range", symbol,
                                "gather index escapes its 4-wide segment"))
    if Kp is not None and not out:
        if not np.array_equal(decode_24(sp), Kp):
            out.append(_finding(cfg, "invariant-roundtrip", symbol,
                                "decode(encode(Kp)) != Kp — placeholder "
                                "rule or metadata corrupt"))
    return out


def verify_kernel(cfg: VetConfig, w: np.ndarray, L: int,
                  symbol: str) -> List[Finding]:
    """Run the full transform pipeline for one 1-D row kernel at one L."""
    out: List[Finding] = []
    try:
        K = kernel_matrix(np.asarray(w, dtype=np.float64), L=L,
                          pad_width=True)
    except ValueError as e:
        return [_finding(cfg, "invariant-banded", symbol,
                         f"kernel_matrix rejected the sweep point: {e}")]
    out += check_kernel_matrix(cfg, K, np.asarray(w, dtype=np.float64), L,
                               symbol)
    perm = strided_swap_perm(L)
    out += check_involution(cfg, perm, symbol)
    Kp = apply_col_perm(K, perm)
    out += check_24_pattern(cfg, Kp, symbol)
    if any(f.rule == "invariant-24" for f in out):
        return out                  # encoding would raise; finding suffices
    sp = encode_24(Kp)
    out += check_sparse24(cfg, sp, Kp, symbol)
    return out


def sweep_points(cfg: VetConfig):
    """(w, L, symbol) for every registry row kernel × radius/L sweep."""
    seen = set()
    # every 1-D row kernel the paper-suite registry can dispatch
    for spec in paper_suite():
        for lead, w in decompose_rows(spec):
            key = (spec.name, tuple(lead))
            if key in seen:
                continue
            seen.add(key)
            base = default_l(spec.radius)
            for L in sorted({base, -(-base // 8) * 8}):
                yield w, L, f"{spec.name}/row{lead}/L{L}"
    # synthetic radius sweep beyond the suite (arbitrary banded contents)
    rng = np.random.default_rng(0)
    for r in cfg.invariant_radii:
        w = rng.uniform(-1.0, 1.0, size=2 * r + 1)
        base = default_l(r)
        for L in sorted({base, base + 2, -(-base // 8) * 8}):
            yield w, L, f"synthetic-r{r}/L{L}"


# ---------------------------------------------------------------------------
# LoweredPlan (IR) invariants — the pipeline as a whole
# ---------------------------------------------------------------------------

#: expected stage-name subsequence per backend family
def _expected_stages(backend: str) -> Tuple[str, ...]:
    if backend in SPARSE_BACKENDS:
        return STAGE_ORDER
    if backend in MATRIX_BACKENDS:
        return tuple(n for n in STAGE_ORDER if n != "strided-swap")
    return (STAGE_ORDER[0], STAGE_ORDER[-1])


def check_lowered_plan(cfg: VetConfig, plan: LoweredPlan,
                       symbol: str) -> List[Finding]:
    """IR-level invariants: stage structure + the shared-pattern property."""
    out: List[Finding] = []
    try:
        plan.validate()
    except ValueError as e:
        return [_finding(cfg, "invariant-plan-stages", symbol,
                         f"plan failed structural validation: {e}",
                         path=_IR_PATH)]
    expected = _expected_stages(plan.emit.backend)
    if plan.stage_names() != expected:
        out.append(_finding(
            cfg, "invariant-plan-stages", symbol,
            f"stage sequence {plan.stage_names()} != expected {expected} "
            f"for backend {plan.emit.backend}", path=_IR_PATH))
    sp, gather = plan.sparsify, plan.gather
    if plan.emit.coefficient_mode == "var" and sp is not None:
        metas = {op.meta.tobytes() for op in sp.operands}
        bits = {op.meta_bits().tobytes() for op in sp.operands}
        if len(metas) > 1 or len(bits) > 1:
            out.append(_finding(
                cfg, "invariant-shared-pattern", symbol,
                f"variable-coefficient operands carry {len(metas)} distinct "
                f"2:4 patterns / {len(bits)} distinct meta-bit packings — "
                "the swap permutation can no longer be computed once",
                path=_IR_PATH))
        elif not sp.shared_pattern:
            out.append(_finding(
                cfg, "invariant-shared-pattern", symbol,
                "operands share one pattern but the plan does not record "
                "shared_pattern=True", path=_IR_PATH))
        if gather is not None and any(
                not np.array_equal(s, gather.slots[0])
                or not np.array_equal(t, gather.taps[0])
                for s, t in zip(gather.slots, gather.taps)):
            out.append(_finding(
                cfg, "invariant-shared-pattern", symbol,
                "variable-coefficient gather schedules differ across "
                "operands — the slot/tap tables must be computed once from "
                "the shared pattern", path=_IR_PATH))
    return out


def plan_probes(cfg: VetConfig) -> Iterator[Tuple[LoweredPlan, str]]:
    """(plan, symbol) probes: const, variable-coefficient, and temporal."""
    rng = np.random.default_rng(0)
    specs = [s for s in paper_suite() if s.ndim <= 2]
    for spec in specs:
        for backend in ("direct", "gemm", "sptc"):
            yield (lower_spec(spec, backend=backend),
                   f"{spec.name}/{backend}")
        # temporal blocking: k is an IR-level attribute, stages unchanged
        yield (lower_spec(spec, backend="sptc", temporal_steps=2),
               f"{spec.name}/sptc/k2")
        # variable coefficients: small random field, star cross respected
        out_shape = (6,) * spec.ndim
        taps = 2 * spec.radius + 1
        c = rng.normal(size=out_shape + (taps,) * spec.ndim)
        if spec.shape == "star":
            c[..., ~star_mask(spec.ndim, spec.radius)] = 0.0
        for backend in ("gemm", "sptc"):
            yield (lower_spec(spec, backend=backend, coefficients=c),
                   f"{spec.name}/{backend}/var")
        if spec.ndim == 2 and spec.shape != "star":
            yield (lower_spec(spec, backend="sptc", fuse_rows=True),
                   f"{spec.name}/sptc/fused")


def run(cfg: VetConfig) -> List[Finding]:
    findings: List[Finding] = []
    for w, L, symbol in sweep_points(cfg):
        findings += verify_kernel(cfg, w, L, symbol)
    for plan, symbol in plan_probes(cfg):
        findings += check_lowered_plan(cfg, plan, symbol)
    return findings
