"""Lowered-HLO purity: certify the zero-runtime-overhead claim statically.

SPIDER's §3.3 contract: the on-the-fly input row swap folds into load
addressing, so the *lowered* sparse hot path must contain no more
gather/permute/copy work than the dense path — the only gather allowed
is the intrinsic im2col window read both paths share.  This analyzer
``jax.jit(...).lower(...).compile()``s the stencil engines on abstract
probe shapes (dry-run; no kernel executes on real data), parses the
optimized HLO with :mod:`repro.roofline.hlo_parse`, and walks the
backward operand closure of every ``dot``:

  lowering-dot-count      #dots != expected (one per 1-D application,
                          one total for the fused-rows engine)
  lowering-hot-gather     gathers feeding the matmul exceed the
                          per-application budget (1 = the window read)
  lowering-hot-overhead   dynamic-slice/dynamic-update-slice in the hot
                          path (runtime-indexed addressing — the op the
                          strided swap exists to avoid)
  lowering-sparse-parity  the sptc path lowers with MORE
                          gather/transpose/copy/dynamic-slice ops than
                          the dense gemm path — runtime overhead the
                          paper claims is zero
  lowering-retrace        a fixed-shape engine traces more than once
                          across repeated calls (retracing hazard)

Temporal-blocked probes scale every budget linearly in the block size k:
a k-step engine must lower with exactly k dots per 1-D application and
one window gather per step — the §3.3 zero-overhead profile holds *per
step*, nothing amortizes into extra runtime addressing work.

The **fused-Pallas analyzer** (``analyze_pallas_fused``) certifies the
same contract for the fused ``pallas_sptc`` kernel, which cannot go
through the optimized-HLO walker (interpret-mode pallas_call bodies are
opaque to it).  It counts primitives in the engine's *jaxpr*, without
descending into pallas_call bodies — what remains is exactly the work
performed OUTSIDE the fused program:

  pallas-fused-program    #pallas_call != one fused program per 1-D
                          application
  pallas-fused-gather     gathers outside the fused program exceed the
                          budget (≤ 1 per application; the shipped kernel
                          achieves 0 — the window DMA lives inside)
  pallas-fused-overhead   dynamic-slice/scatter outside the program, or
                          more transpose/gather ops than the dense
                          pallas_mxu engine lowers with — a standalone
                          permute that failed to fold into the kernel

The **sharded analyzer** (``analyze_sharded``) certifies the distributed
halo-exchange hot path (``distributed/halo.py``) when this process sees
more than one device:

  sharded-collective-budget  a fused k-step lowers with != 2
                          collective-permutes per partitioned mesh axis
                          (low + high edge; zero-flux boundary is free)
  sharded-all-gather      anything gather-shaped (all-gather, all-reduce,
                          all-to-all) on the sharded hot path — the
                          partitioner rematerialized the global grid

``verdict()`` additionally returns the per-backend op counts (keyed by
kernel name: ``stencil_gemm``, ``sptc_spmm``, ``sptc_spmm_fused``) that
the CLI emits as the certified zero-overhead status.
"""
from __future__ import annotations

import inspect
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import StencilEngine
from repro.core.stencil import StencilSpec, make_stencil
from repro.core.transform import decompose_rows
from repro.roofline import hlo_parse
from repro.vet.config import VetConfig
from repro.vet.findings import Finding

_PATH = "src/repro/core/engine.py"

#: engine backend -> the kernel subsystem its lowering certifies
BACKEND_KERNEL = {"gemm": "stencil_gemm", "sptc": "sptc_spmm"}

#: opcodes whose presence in the hot path is runtime overhead to account
OVERHEAD_OPS = ("gather", "transpose", "copy", "dynamic-slice",
                "dynamic-update-slice")

#: (spec ctor args, fuse_rows, temporal steps, probe input shape) —
#: small, compile-fast; the k=2 probe certifies the per-step profile
PROBES: Tuple[Tuple[Tuple[str, int, int], bool, int, Tuple[int, ...]],
              ...] = (
    (("star", 2, 1), False, 1, (34, 34)),
    (("box", 2, 1), True, 1, (34, 34)),
    (("star", 2, 1), False, 2, (36, 36)),
)


def _finding(cfg: VetConfig, rule: str, symbol: str, message: str) -> Finding:
    return Finding(rule=rule, severity=cfg.severity_of(rule), path=_PATH,
                   line=0, symbol=symbol, message=message)


def n_applications(spec: StencilSpec, fused: bool) -> int:
    """1-D applications the engine performs (== expected dot count)."""
    if fused:
        return 1
    if spec.ndim == 1:
        return 1
    if spec.shape == "star":
        return spec.ndim
    return len(decompose_rows(spec))


def lower_engine(engine: StencilEngine,
                 shape: Tuple[int, ...]) -> hlo_parse.HotPathReport:
    """Optimized-HLO hot-path report for one engine at one probe shape."""
    fn = inspect.unwrap(engine._fn)
    x = jax.ShapeDtypeStruct(shape, jnp.float32)
    text = jax.jit(fn).lower(x).compile().as_text()
    return hlo_parse.hot_path(text)


def hot_counts(report: hlo_parse.HotPathReport) -> Dict[str, int]:
    hist = report.histogram()
    counts = {op: hist.get(op, 0) for op in OVERHEAD_OPS}
    counts["dot"] = len(report.dots)
    return counts


def trace_count(engine: StencilEngine, shape: Tuple[int, ...],
                calls: int = 3) -> int:
    """How many times the engine function traces across same-shape calls."""
    fn = inspect.unwrap(engine._fn)
    n = [0]

    def counting(x):
        n[0] += 1
        return fn(x)

    jitted = jax.jit(counting)
    rng = np.random.default_rng(0)
    for _ in range(max(1, calls)):
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        jax.block_until_ready(jitted(x))
    return n[0]


def analyze_backend(cfg: VetConfig, backend: str
                    ) -> Tuple[List[Finding], Dict[str, dict]]:
    """Findings + per-probe op counts for one engine backend."""
    findings: List[Finding] = []
    per_probe: Dict[str, dict] = {}
    kernel = BACKEND_KERNEL.get(backend, backend)
    budget = cfg.lowering_budgets.get(backend, {})
    for (shape_kind, ndim, radius), fused, steps, probe_shape in PROBES:
        spec = make_stencil(shape_kind, ndim, radius, seed=7)
        symbol = (f"{kernel}/{spec.name}{'/fused' if fused else ''}"
                  f"{f'/k{steps}' if steps != 1 else ''}")
        engine = StencilEngine(spec, backend=backend, fuse_rows=fused,
                               temporal_steps=steps)
        report = lower_engine(engine, probe_shape)
        counts = hot_counts(report)
        per_probe[symbol] = counts
        # every budget scales linearly in the temporal block size: the
        # zero-overhead profile must hold per step (§3.3)
        napps = n_applications(spec, fused) * steps
        if counts["dot"] != napps:
            findings.append(_finding(
                cfg, "lowering-dot-count", symbol,
                f"expected {napps} dot(s) (one per 1-D application per "
                f"step), lowered program has {counts['dot']}"))
        gather_budget = budget.get("gather", 1) * napps
        if counts["gather"] > gather_budget:
            findings.append(_finding(
                cfg, "lowering-hot-gather", symbol,
                f"{counts['gather']} gather(s) feed the matmul hot path "
                f"(budget {gather_budget}: the im2col window read only) — "
                "a row swap or metadata gather failed to fold into load "
                "addressing (§3.3)"))
        dyn = counts["dynamic-slice"] + counts["dynamic-update-slice"]
        dyn_budget = budget.get("dynamic-slice", 0) * napps
        if dyn > dyn_budget:
            findings.append(_finding(
                cfg, "lowering-hot-overhead", symbol,
                f"{dyn} dynamic-slice op(s) feed the matmul hot path "
                f"(budget {dyn_budget}) — runtime-indexed addressing in a "
                "statically-known access pattern"))
    return findings, per_probe


# ---------------------------------------------------------------------------
# Fused-Pallas kernel: jaxpr-level certification (interpret-mode safe —
# tracing only, the kernel never executes here)
# ---------------------------------------------------------------------------

#: ops that, OUTSIDE the fused program, constitute runtime overhead
_JAXPR_OVERHEAD = ("gather", "transpose", "dynamic_slice",
                   "dynamic_update_slice", "scatter")

#: (spec ctor args, probe input shape) — star exercises the metadata-free
#: fast path, box the faithful one-hot decompression path
PALLAS_PROBES: Tuple[Tuple[Tuple[str, int, int], Tuple[int, ...]], ...] = (
    (("star", 2, 1), (22, 22)),
    (("box", 2, 1), (22, 22)),
)


def _subjaxprs(val):
    vals = val if isinstance(val, (tuple, list)) else (val,)
    for v in vals:
        if hasattr(v, "jaxpr"):            # ClosedJaxpr
            yield v.jaxpr
        elif hasattr(v, "eqns"):           # Jaxpr
            yield v


def _walk_jaxpr(jaxpr, counts: Dict[str, int]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        counts[name] = counts.get(name, 0) + 1
        if name == "pallas_call":
            continue                       # the fused program is the budget
        for param in eqn.params.values():
            for sub in _subjaxprs(param):
                _walk_jaxpr(sub, counts)


def jaxpr_counts(engine: StencilEngine,
                 shape: Tuple[int, ...]) -> Dict[str, int]:
    """Primitive histogram of the engine's jaxpr, pallas bodies excluded."""
    fn = inspect.unwrap(engine._fn)
    closed = jax.make_jaxpr(fn)(jnp.zeros(shape, jnp.float32))
    counts: Dict[str, int] = {}
    _walk_jaxpr(closed.jaxpr, counts)
    return counts


def analyze_pallas_fused(cfg: VetConfig
                         ) -> Tuple[List[Finding], Dict[str, dict]]:
    """Certify the fused pallas_sptc kernel's zero-overhead profile."""
    findings: List[Finding] = []
    per_probe: Dict[str, dict] = {}
    budget = cfg.lowering_budgets.get("pallas_sptc", {})
    for (shape_kind, ndim, radius), probe_shape in PALLAS_PROBES:
        spec = make_stencil(shape_kind, ndim, radius, seed=7)
        symbol = f"sptc_spmm_fused/{spec.name}"
        engine = StencilEngine(spec, backend="pallas_sptc")
        counts = jaxpr_counts(engine, probe_shape)
        dense = jaxpr_counts(StencilEngine(spec, backend="pallas_mxu"),
                             probe_shape)
        keep = dict.fromkeys(_JAXPR_OVERHEAD, 0)
        keep.update({k: v for k, v in counts.items()
                     if k in _JAXPR_OVERHEAD or k == "pallas_call"})
        keep.setdefault("pallas_call", 0)
        per_probe[symbol] = keep
        napps = n_applications(spec, fused=False)
        if keep["pallas_call"] != napps:
            findings.append(_finding(
                cfg, "pallas-fused-program", symbol,
                f"expected {napps} fused pallas program(s) (one per 1-D "
                f"application), traced {keep['pallas_call']}"))
        gather_budget = budget.get("gather", 1) * napps
        if keep["gather"] > gather_budget:
            findings.append(_finding(
                cfg, "pallas-fused-gather", symbol,
                f"{keep['gather']} gather(s) outside the fused program "
                f"(budget {gather_budget}) — windowing/swap/metadata work "
                "failed to fold into the kernel (§3.3)"))
        dyn = (keep["dynamic_slice"] + keep["dynamic_update_slice"]
               + keep["scatter"])
        if dyn > budget.get("dynamic-slice", 0) * napps:
            findings.append(_finding(
                cfg, "pallas-fused-overhead", symbol,
                f"{dyn} dynamic-slice/scatter op(s) outside the fused "
                "program — runtime-indexed addressing in a statically-"
                "known access pattern"))
        for op in ("gather", "transpose"):
            if keep[op] > dense.get(op, 0):
                findings.append(_finding(
                    cfg, "pallas-fused-overhead", symbol,
                    f"{keep[op]} {op} op(s) outside the fused program vs "
                    f"the dense pallas_mxu engine's {dense.get(op, 0)} — a "
                    "standalone permute the paper's row swap eliminates"))
    return findings, per_probe


# ---------------------------------------------------------------------------
# Sharded halo exchange: collective budget on the distributed hot path
# ---------------------------------------------------------------------------

_SHARDED_PATH = "src/repro/distributed/halo.py"

#: opcodes that would mean the partitioner fell back to gathering the
#: whole grid instead of exchanging width-k·r halos
_GATHER_LIKE = ("all-gather", "all-to-all", "all-reduce", "reduce-scatter")


def _collective_counts(text: str) -> Dict[str, int]:
    hist = hlo_parse.opcode_histogram(hlo_parse.parse_module(text))
    permutes = (hist.get("collective-permute", 0)
                + hist.get("collective-permute-start", 0))
    gathers = sum(hist.get(op, 0) + hist.get(op + "-start", 0)
                  for op in _GATHER_LIKE)
    return {"collective-permute": permutes, "gather-like": gathers}


def sharded_probes() -> Tuple[Tuple[Tuple[str, int, int], tuple, int,
                                    Tuple[int, ...]], ...]:
    """(spec ctor args, mesh parts, temporal steps, probe interior shape),
    scaled to however many devices this process sees."""
    n = jax.device_count()
    if n < 2:
        return ()
    probes = [
        (("star", 2, 1), (2,), 1, (24, 24)),
        (("box", 2, 1), (2,), 2, (24, 24)),
    ]
    if n >= 4:
        probes.append((("box", 2, 2), (2, 2), 1, (24, 24)))
    return tuple(probes)


def analyze_sharded(cfg: VetConfig
                    ) -> Tuple[List[Finding], Dict[str, dict]]:
    """Certify the halo-exchange collective budget on the step hot path.

    The distributed contract: one fused k-step exchanges exactly TWO
    collective-permutes per partitioned axis (low edge + high edge; the
    zero-flux boundary is ppermute's zero fill, costing nothing extra)
    and nothing gather-shaped — an all-gather in the lowered program
    means the partitioner rematerialized the global grid.  Probes lower
    ``ShardedStencilEngine``'s device-resident step/iterate path (the
    one-time halo-inclusive ``__call__`` boundary reshard is not the
    steady state).  Needs >= 2 devices; returns empty findings and
    probes otherwise (CI supplies virtual devices via
    ``--xla_force_host_platform_device_count``).
    """
    findings: List[Finding] = []
    per_probe: Dict[str, dict] = {}
    from repro.distributed.halo import ShardedStencilEngine, grid_mesh
    for (shape_kind, ndim, radius), parts, steps, shape in sharded_probes():
        spec = make_stencil(shape_kind, ndim, radius, seed=7)
        mesh_tag = "x".join(str(p) for p in parts)
        symbol = (f"halo/{spec.name}/mesh{mesh_tag}"
                  f"{f'/k{steps}' if steps != 1 else ''}")
        engine = ShardedStencilEngine(spec, grid_mesh(parts),
                                      backend="sptc",
                                      temporal_steps=steps)
        naxes = len(engine.partition())
        u = jax.ShapeDtypeStruct(shape, jnp.float32)
        for tag, nblocks in (("step", 1), ("iterate", 2)):
            text = jax.jit(engine._run_sharded, static_argnums=1).lower(
                u, nblocks).compile().as_text()
            counts = _collective_counts(text)
            per_probe[f"{symbol}/{tag}"] = counts
            expected = 2 * naxes
            if counts["collective-permute"] != expected:
                findings.append(Finding(
                    rule="sharded-collective-budget",
                    severity=cfg.severity_of("sharded-collective-budget"),
                    path=_SHARDED_PATH, line=0, symbol=f"{symbol}/{tag}",
                    message=(
                        f"expected exactly {expected} collective-permutes "
                        f"per fused step (2 per partitioned axis × {naxes} "
                        f"axes), lowered program has "
                        f"{counts['collective-permute']}")))
            if counts["gather-like"]:
                findings.append(Finding(
                    rule="sharded-all-gather",
                    severity=cfg.severity_of("sharded-all-gather"),
                    path=_SHARDED_PATH, line=0, symbol=f"{symbol}/{tag}",
                    message=(
                        f"{counts['gather-like']} all-gather/all-reduce/"
                        "all-to-all op(s) on the sharded hot path — the "
                        "partitioner rematerialized the global grid "
                        "instead of exchanging width-k·r halos")))
    return findings, per_probe


def run(cfg: VetConfig) -> Tuple[List[Finding], Dict[str, dict]]:
    """All lowering findings + the per-backend zero-overhead verdict."""
    findings: List[Finding] = []
    verdict: Dict[str, dict] = {}
    counts_by_backend: Dict[str, Dict[str, dict]] = {}
    for backend in cfg.lowering_backends:
        fs, per_probe = analyze_backend(cfg, backend)
        findings += fs
        counts_by_backend[backend] = per_probe
        kernel = BACKEND_KERNEL.get(backend, backend)
        verdict[kernel] = {
            "probes": per_probe,
            "certified": not fs,
        }
    # sparse-vs-dense parity: sptc may not out-gather/out-copy gemm
    if "gemm" in counts_by_backend and "sptc" in counts_by_backend:
        dense = counts_by_backend["gemm"]
        sparse = counts_by_backend["sptc"]
        for d_sym, s_sym in zip(sorted(dense), sorted(sparse)):
            for op in OVERHEAD_OPS:
                if sparse[s_sym][op] > dense[d_sym][op]:
                    f = _finding(
                        cfg, "lowering-sparse-parity", s_sym,
                        f"sptc hot path has {sparse[s_sym][op]} {op} op(s) "
                        f"vs gemm's {dense[d_sym][op]} — sparse execution "
                        "added runtime overhead the paper claims is zero")
                    findings.append(f)
                    verdict["sptc_spmm"]["certified"] = False
    # fused Pallas kernel: jaxpr-level zero-overhead certification
    fused_findings, fused_probes = analyze_pallas_fused(cfg)
    findings += fused_findings
    verdict["sptc_spmm_fused"] = {
        "probes": fused_probes,
        "certified": not fused_findings,
    }
    # distributed halo exchange: collective budget per partitioned axis
    # (probes exist only when this process sees >= 2 devices)
    sharded_findings, sharded_probes_ran = analyze_sharded(cfg)
    findings += sharded_findings
    if sharded_probes_ran:
        verdict["sharded_halo"] = {
            "probes": sharded_probes_ran,
            "certified": not sharded_findings,
        }
    # retracing: a fixed-shape engine must trace exactly once
    for backend in cfg.lowering_backends:
        kernel = BACKEND_KERNEL.get(backend, backend)
        spec = make_stencil("star", 2, 1, seed=7)
        engine = StencilEngine(spec, backend=backend)
        traces = trace_count(engine, (34, 34))
        verdict[kernel]["traces"] = traces
        if traces != 1:
            findings.append(_finding(
                cfg, "lowering-retrace", f"{kernel}/{spec.name}",
                f"fixed-shape engine traced {traces} times over 3 "
                "same-shape calls — retracing hazard in the hot path"))
            verdict[kernel]["certified"] = False
    return findings, verdict
