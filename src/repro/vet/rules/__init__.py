"""Registry of the repro-specific AST rules applied by the code analyzer."""
from __future__ import annotations

from typing import List

from repro.vet.rules.base import Rule, RuleContext
from repro.vet.rules.host_sync import HostSyncRule
from repro.vet.rules.jit_hot_path import JitHotPathRule
from repro.vet.rules.lock_discipline import (LockDisciplineRule,
                                             LockedSuffixRule)
from repro.vet.rules.nondet_key import NondetKeyRule

ALL_RULES: List[Rule] = [
    JitHotPathRule(),
    HostSyncRule(),
    LockDisciplineRule(),
    LockedSuffixRule(),
    NondetKeyRule(),
]

__all__ = ["ALL_RULES", "Rule", "RuleContext", "HostSyncRule",
           "JitHotPathRule", "LockDisciplineRule", "LockedSuffixRule",
           "NondetKeyRule"]
