"""Shared AST machinery for the repro-specific code rules.

Each rule is a :class:`Rule` subclass with a stable ``rule_id`` and a
``check(ctx)`` returning findings.  ``RuleContext`` carries the parsed
tree, the repo-relative path, and the vet config (hot-path module and
function lists, per-rule severities).  Setting a code rule's severity to
``"off"`` in ``[tool.repro-vet.severity]`` disables it.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, List, Optional, Tuple

from repro.vet.config import VetConfig
from repro.vet.findings import Finding


@dataclasses.dataclass
class RuleContext:
    cfg: VetConfig
    path: str                       # repo-relative, forward slashes
    tree: ast.Module

    def is_hot_module(self) -> bool:
        parts = self.path.replace("\\", "/").split("/")
        return any(m in parts for m in self.cfg.hot_path_modules)

    def is_hot_function(self, name: str) -> bool:
        return name in self.cfg.hot_path_functions


class Rule:
    rule_id: str = ""
    description: str = ""

    def check(self, ctx: RuleContext) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: RuleContext, line: int, symbol: str,
                message: str) -> Optional[Finding]:
        sev = ctx.cfg.severity_of(self.rule_id)
        if sev == "off":
            return None
        return Finding(rule=self.rule_id, severity=sev, path=ctx.path,
                       line=line, symbol=symbol, message=message)


def dotted_name(node: ast.AST) -> str:
    """'jax.jit' for Name/Attribute chains; '' for anything dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(node: ast.Call) -> str:
    return dotted_name(node.func)


def iter_functions(tree: ast.Module
                   ) -> Iterator[Tuple[str, ast.AST, Optional[ast.ClassDef]]]:
    """(qualname, function node, enclosing class) for every def/async def."""

    def walk(node: ast.AST, prefix: str, cls: Optional[ast.ClassDef]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child, cls
                yield from walk(child, qual + ".", cls)
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.", child)

    yield from walk(tree, "", None)


def enclosing_map(func: ast.AST) -> dict:
    """node -> parent map for one function body."""
    parents = {}
    for parent in ast.walk(func):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    return parents


def inside(node: ast.AST, parents: dict, kinds: tuple) -> Optional[ast.AST]:
    """The nearest ancestor of ``node`` matching ``kinds``, if any."""
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, kinds):
            return cur
        cur = parents.get(cur)
    return None


def with_lock_items(node: ast.With, lock_attrs: set) -> bool:
    """True if a ``with`` statement acquires one of the class's locks."""
    for item in node.items:
        expr = item.context_expr
        # `with self._lock:` / `with self._cond:`
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                and expr.attr in lock_attrs:
            return True
    return False


def self_attr(node: ast.AST) -> Optional[str]:
    """'x' when node is exactly ``self.x``."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None
