"""code-host-sync: device->host synchronization inside serving/tuner hot paths.

``np.asarray`` / ``np.array`` on a device array, ``jax.device_get``,
``.block_until_ready()``, ``.item()`` and ``.tolist()`` all stall the
Python thread until the device catches up.  On an admission or batch-
execution path that serializes the pipeline — the device drains while
the scheduler waits, killing the continuous-batching overlap.

The rule fires only inside hot-path functions (``submit``,
``_run_batch``, ...; configurable) of hot-path modules.  Intentional
syncs (e.g. anchoring a latency metric to real completion) belong in
the baseline with a documented reason.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.vet.findings import Finding
from repro.vet.rules.base import (Rule, RuleContext, call_name,
                                  iter_functions)

SYNC_CALLS = ("np.asarray", "np.array", "numpy.asarray", "numpy.array",
              "jax.device_get")
SYNC_METHODS = ("block_until_ready", "item", "tolist")


class HostSyncRule(Rule):
    rule_id = "code-host-sync"
    description = ("host synchronization (np.asarray / float() / "
                   ".block_until_ready()) inside serving/tuner hot paths")

    def check(self, ctx: RuleContext) -> List[Finding]:
        if not ctx.is_hot_module():
            return []
        out: List[Finding] = []
        for qual, func, _cls in iter_functions(ctx.tree):
            name = qual.rsplit(".", 1)[-1]
            if not ctx.is_hot_function(name):
                continue
            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                msg = self._classify(node)
                if msg is None:
                    continue
                f = self.finding(ctx, node.lineno, qual, msg)
                if f:
                    out.append(f)
        return out

    @staticmethod
    def _classify(node: ast.Call) -> Optional[str]:
        cn = call_name(node)
        if cn in SYNC_CALLS:
            return (f"{cn}(...) forces a device->host transfer on a hot "
                    "path — keep results on device (jnp) until the caller "
                    "asks")
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in SYNC_METHODS:
            return (f".{node.func.attr}() blocks the scheduling thread "
                    "until the device drains — overlap is lost for every "
                    "queued batch behind it")
        if cn == "float" and node.args \
                and not isinstance(node.args[0], ast.Constant):
            return ("float(...) on a non-literal may force a device sync "
                    "if the value is a traced/device scalar")
        return None
