"""code-jit-per-call: ``jax.jit`` constructed inside serving/tuner call paths.

A ``jax.jit(...)`` call that executes per request builds a *new* jitted
callable every time — every invocation retraces and recompiles, which is
exactly the re-jit-per-call pattern the plan/engine cache exists to kill.
Inside hot-path modules the rule flags any jit/pjit construction inside a
function body unless it is provably one-time or memoized:

  * constructed in ``__init__``/``__post_init__``/``__new__`` (object
    construction happens once per engine, not per request);
  * the result is stored into a container slot (``cache[k] = fn`` — the
    memoization idiom of ``tuner/cache.py``), directly or via a local;
  * at module level (import time).

A jit construction inside a loop is flagged unconditionally.
"""
from __future__ import annotations

import ast
from typing import List

from repro.vet.findings import Finding
from repro.vet.rules.base import (Rule, RuleContext, call_name,
                                  enclosing_map, inside, iter_functions)

JIT_CALLS = ("jax.jit", "jit", "pjit", "jax.pjit")
CTOR_NAMES = ("__init__", "__post_init__", "__new__")


class JitHotPathRule(Rule):
    rule_id = "code-jit-per-call"
    description = ("jax.jit constructed inside per-request serving/tuner "
                   "call paths (retracing hazard)")

    def check(self, ctx: RuleContext) -> List[Finding]:
        if not ctx.is_hot_module():
            return []
        out: List[Finding] = []
        for qual, func, _cls in iter_functions(ctx.tree):
            name = qual.rsplit(".", 1)[-1]
            parents = enclosing_map(func)
            # locals that ever get stored into a container slot
            memoized_locals = set()
            for node in ast.walk(func):
                if isinstance(node, ast.Assign):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Subscript) and \
                                isinstance(node.value, ast.Name):
                            memoized_locals.add(node.value.id)
            for node in ast.walk(func):
                if not (isinstance(node, ast.Call)
                        and call_name(node) in JIT_CALLS):
                    continue
                # skip jit calls belonging to a nested def (handled there)
                owner = inside(node, parents,
                               (ast.FunctionDef, ast.AsyncFunctionDef))
                if owner is not None and owner is not func:
                    continue
                in_loop = inside(node, parents, (ast.For, ast.While))
                if in_loop is not None:
                    f = self.finding(
                        ctx, node.lineno, qual,
                        "jax.jit constructed inside a loop — retraces and "
                        "recompiles every iteration")
                    if f:
                        out.append(f)
                    continue
                if name in CTOR_NAMES:
                    continue
                assign = parents.get(node)
                if isinstance(assign, ast.Assign):
                    tgts = assign.targets
                    if any(isinstance(t, ast.Subscript) for t in tgts):
                        continue                      # cache[k] = jax.jit(...)
                    if any(isinstance(t, ast.Name)
                           and t.id in memoized_locals for t in tgts):
                        continue                      # fn = jit(..); cache[k]=fn
                f = self.finding(
                    ctx, node.lineno, qual,
                    "jax.jit constructed in a per-request call path — build "
                    "once (constructor) or memoize it (plan/engine cache)")
                if f:
                    out.append(f)
        return out
