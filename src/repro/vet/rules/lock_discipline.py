"""code-lock-discipline / code-locked-suffix: shared-state locking lint.

The serving layer runs a scheduler thread plus N caller threads; its
convention is: shared attributes are mutated only under ``with
self._lock:`` (or ``self._cond``), and methods that *assume* the lock is
already held carry a ``_locked`` suffix and are only called from inside
a with-lock block (or from another ``*_locked`` method).

  code-lock-discipline   an attribute of ``self`` is mutated both under
                         a lock and outside one (outside ``__init__``) —
                         at least one of the two sites is a data race
  code-locked-suffix     a ``self.foo_locked(...)`` call happens outside
                         any with-lock block in a method that is not
                         itself ``*_locked``

Lock attributes are discovered from ``__init__``: any ``self.X =
threading.Lock()/RLock()/Condition()`` assignment.  Classes without a
lock attribute are skipped entirely — single-threaded helpers don't
carry a locking convention to enforce.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.vet.findings import Finding
from repro.vet.rules.base import (Rule, RuleContext, call_name,
                                  enclosing_map, inside, self_attr,
                                  with_lock_items)

LOCK_CTORS = ("threading.Lock", "threading.RLock", "threading.Condition",
              "Lock", "RLock", "Condition")

#: method names that mutate their receiver in place
MUTATING_METHODS = ("append", "appendleft", "extend", "add", "remove",
                    "discard", "pop", "popleft", "clear", "update",
                    "setdefault", "insert", "sort")


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    """Names of self attributes bound to a threading primitive in __init__."""
    locks: Set[str] = set()
    for node in cls.body:
        if not (isinstance(node, ast.FunctionDef) and node.name == "__init__"):
            continue
        for stmt in ast.walk(node):
            if not isinstance(stmt, ast.Assign):
                continue
            if not (isinstance(stmt.value, ast.Call)
                    and call_name(stmt.value) in LOCK_CTORS):
                continue
            for tgt in stmt.targets:
                attr = self_attr(tgt)
                if attr:
                    locks.add(attr)
    return locks


def _mutated_attr(node: ast.AST, parents: dict) -> Optional[str]:
    """The self-attribute ``node`` mutates, if it is a mutation site."""
    if isinstance(node, ast.AugAssign):                 # self.x += 1
        return self_attr(node.target)
    if isinstance(node, ast.Assign):                    # self.x = v / self.d[k]=v
        for tgt in node.targets:
            attr = self_attr(tgt)
            if attr:
                return attr
            if isinstance(tgt, ast.Subscript):
                attr = self_attr(tgt.value)
                if attr:
                    return attr
        return None
    if isinstance(node, ast.Call) \
            and isinstance(node.func, ast.Attribute) \
            and node.func.attr in MUTATING_METHODS:     # self.q.append(v)
        return self_attr(node.func.value)
    return None


class LockDisciplineRule(Rule):
    rule_id = "code-lock-discipline"
    description = ("self attribute mutated both under and outside the "
                   "instance lock (data race)")

    def check(self, ctx: RuleContext) -> List[Finding]:
        if not ctx.is_hot_module():
            return []
        out: List[Finding] = []
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = _lock_attrs(cls)
            if not locks:
                continue
            # attr -> {"locked": [(qual, line)], "unlocked": [(qual, line)]}
            sites: Dict[str, Dict[str, List[Tuple[str, int]]]] = {}
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name == "__init__":
                    continue
                parents = enclosing_map(meth)
                qual = f"{cls.name}.{meth.name}"
                # *_locked methods run with the lock held by convention
                held_by_name = meth.name.endswith("_locked")
                for node in ast.walk(meth):
                    attr = _mutated_attr(node, parents)
                    if attr is None or attr in locks:
                        continue
                    w = inside(node, parents, (ast.With, ast.AsyncWith))
                    held = held_by_name
                    while w is not None and not held:
                        if with_lock_items(w, locks):
                            held = True
                        w = inside(w, parents, (ast.With, ast.AsyncWith))
                    bucket = "locked" if held else "unlocked"
                    sites.setdefault(attr, {"locked": [], "unlocked": []})
                    sites[attr][bucket].append((qual, node.lineno))
            for attr, s in sorted(sites.items()):
                if not (s["locked"] and s["unlocked"]):
                    continue
                for qual, line in s["unlocked"]:
                    locked_at = ", ".join(
                        f"{q}:{ln}" for q, ln in s["locked"][:3])
                    f = self.finding(
                        ctx, line, qual,
                        f"self.{attr} mutated without the lock here but "
                        f"under it at {locked_at} — one of the two sites "
                        "races")
                    if f:
                        out.append(f)
        return out


class LockedSuffixRule(Rule):
    rule_id = "code-locked-suffix"
    description = ("*_locked method called without holding the instance "
                   "lock")

    def check(self, ctx: RuleContext) -> List[Finding]:
        if not ctx.is_hot_module():
            return []
        out: List[Finding] = []
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = _lock_attrs(cls)
            if not locks:
                continue
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name.endswith("_locked"):
                    continue            # callee context: lock already held
                parents = enclosing_map(meth)
                qual = f"{cls.name}.{meth.name}"
                for node in ast.walk(meth):
                    if not (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Attribute)
                            and node.func.attr.endswith("_locked")
                            and self_attr(node.func) is not None):
                        continue
                    held = False
                    w = inside(node, parents, (ast.With, ast.AsyncWith))
                    while w is not None and not held:
                        if with_lock_items(w, locks):
                            held = True
                        w = inside(w, parents, (ast.With, ast.AsyncWith))
                    if held:
                        continue
                    f = self.finding(
                        ctx, node.lineno, qual,
                        f"self.{node.func.attr}() assumes the lock is held "
                        "(by naming convention) but no enclosing with-lock "
                        "block acquires it")
                    if f:
                        out.append(f)
        return out
