"""code-nondet-key: unordered collections flowing into cache-key strings.

The plan cache keys plans by a string/tuple fingerprint; iterating a
``set``/``frozenset`` (or ``dict`` whose insertion order is
call-dependent) while building that fingerprint makes the key depend on
iteration order — two processes (or two runs under hash randomization)
compute different keys for the same plan, silently duplicating cache
entries and invalidating persisted plans.

The rule scans functions whose name mentions ``key`` / ``fingerprint`` /
``cache_token`` and flags joins or tuple/str constructions over an
expression that is syntactically a set (set literal, ``set(...)``,
``frozenset(...)``, ``SetComp``) unless it is wrapped in ``sorted(...)``.
"""
from __future__ import annotations

import ast
from typing import List

from repro.vet.findings import Finding
from repro.vet.rules.base import (Rule, RuleContext, call_name,
                                  enclosing_map, iter_functions)

KEYISH = ("key", "fingerprint", "cache_token")


def _is_setlike(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and call_name(node) in ("set", "frozenset"):
        return True
    return False


class NondetKeyRule(Rule):
    rule_id = "code-nondet-key"
    description = ("set iteration order leaks into a cache key / "
                   "fingerprint (nondeterministic across processes)")

    def check(self, ctx: RuleContext) -> List[Finding]:
        if not ctx.is_hot_module():
            return []
        out: List[Finding] = []
        for qual, func, _cls in iter_functions(ctx.tree):
            name = qual.rsplit(".", 1)[-1].lower()
            if not any(k in name for k in KEYISH):
                continue
            parents = enclosing_map(func)
            # set-typed local names (x = {..} / x = set(..))
            set_locals = set()
            for node in ast.walk(func):
                if isinstance(node, ast.Assign) and _is_setlike(node.value):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            set_locals.add(tgt.id)

            def setlike(expr: ast.AST) -> bool:
                return _is_setlike(expr) or (
                    isinstance(expr, ast.Name) and expr.id in set_locals)

            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                cn = call_name(node)
                if cn == "sorted":
                    continue
                bad = None
                # ".".join(s) / str(s) / tuple(s) / list(s) over a set
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "join" and node.args:
                    if setlike(node.args[0]):
                        bad = node.args[0]
                elif cn in ("str", "tuple", "list", "repr") and node.args:
                    if setlike(node.args[0]):
                        bad = node.args[0]
                if bad is None:
                    continue
                # sorted(...) anywhere between the set and the sink is fine
                cur = parents.get(bad)
                shielded = False
                while cur is not None and cur is not func:
                    if isinstance(cur, ast.Call) \
                            and call_name(cur) == "sorted":
                        shielded = True
                        break
                    cur = parents.get(cur)
                if shielded:
                    continue
                f = self.finding(
                    ctx, node.lineno, qual,
                    "set iteration order flows into a key/fingerprint — "
                    "wrap the set in sorted(...) to make the key "
                    "deterministic across processes")
                if f:
                    out.append(f)
        return out
