"""Import hypothesis, or provide skipping stand-ins.

hypothesis is a dev extra (requirements-dev.txt); the property-based
tests skip without it while deterministic sweeps run unconditionally.
Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
import pytest

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(**_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(**_kwargs):
        return lambda fn: fn

    class st:  # noqa: N801 — stand-in namespace, never executed
        integers = staticmethod(lambda *a, **k: None)
