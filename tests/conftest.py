"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — smoke tests and kernel tests
must see the single real CPU device (the 512-device placeholder mesh belongs
exclusively to launch/dryrun.py, which sets the flag before importing jax).
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
