"""Analytic cost model tests — must reproduce paper Table 1 (Box-2D3R, c=8,
TCStencil L=16) and the §2.3 asymptotic redundancy bounds."""
import numpy as np

from repro.core import analysis


def test_table1_matches_paper():
    t = analysis.table1(r=3, c=8)
    lb = t["lower_bound"]
    assert lb.macs == 49
    np.testing.assert_allclose(lb.input_access, (8 + 6) ** 2 / 64)  # 3.0625
    np.testing.assert_allclose(lb.param_access, 49 / 64)            # 0.7656
    np.testing.assert_allclose(t["tcstencil"].macs, 286.72)
    np.testing.assert_allclose(t["tcstencil"].input_access, 17.92)
    np.testing.assert_allclose(t["convstencil"].macs, 104)
    np.testing.assert_allclose(t["convstencil"].input_access, 13)
    np.testing.assert_allclose(t["convstencil"].param_access, 13)
    np.testing.assert_allclose(t["lorastencil"].macs, 144)
    np.testing.assert_allclose(t["lorastencil"].input_access, 4)
    np.testing.assert_allclose(t["lorastencil"].param_access, 12)
    np.testing.assert_allclose(t["sptcstencil"].macs, 56)
    np.testing.assert_allclose(t["sptcstencil"].input_access, 14)
    np.testing.assert_allclose(t["sptcstencil"].param_access, 7)


def test_sptc_beats_dense_tc_baselines():
    """Paper's headline: SPTCStencil cuts MACs >= ~2x vs dense TC methods."""
    for r in (1, 2, 3):
        s = analysis.sptcstencil(r)
        assert analysis.tcstencil(r).macs / s.macs > 2.0
        assert analysis.convstencil(r).macs >= s.macs
        assert s.param_access <= analysis.convstencil(r).param_access


def test_redundancy_lower_bounds_of_baselines():
    """§2.3: ConvStencil > 2x LB; TCStencil >= 4.5x LB at r=3."""
    lb = analysis.lower_bound(3).macs
    assert analysis.convstencil(3).macs > 2 * lb
    assert analysis.tcstencil(3).macs >= 4.5 * lb
    assert analysis.lorastencil(3).macs >= 1.29 * lb


def test_tpu_im2col_hits_mac_lower_bound():
    """Our beyond-paper TPU kernel: exactly the (2r+1)^2 MAC lower bound."""
    for r in (1, 2, 3):
        assert analysis.tpu_im2col(r).macs == analysis.lower_bound(r).macs


def test_mxu_k_occupancy():
    # K = (2r+1)^2: 9/128, 25/128, 49/128
    np.testing.assert_allclose(analysis.mxu_k_occupancy(1), 9 / 128)
    np.testing.assert_allclose(analysis.mxu_k_occupancy(3), 49 / 128)


def test_sptc_halves_the_dense_padded_gemm():
    """The compressed SpMM executes K/2: exactly half the padded dense GEMM's
    reduction work — the 2x SpTC skip the paper exploits."""
    r, c = 3, 8
    dense_k = 4 * -(-(2 * r + c) // 4)
    s = analysis.sptcstencil(r, c)
    rows = 2 * r + 1
    dense_macs = rows * 8 * 8 * dense_k / c ** 2
    np.testing.assert_allclose(s.macs, dense_macs / 2)
