"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train-loss step + one prefill->decode step on CPU, asserting
output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, input_specs
from repro.configs.base import SHAPE_CELLS
from repro.models import model as M
from repro.models.nn import count_params
from repro.serving import engine as E


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    tokens = jax.random.randint(k, (b, s), 0, cfg.vocab)
    mem = None
    if cfg.family == "vlm":
        mem = jax.random.normal(k, (b, cfg.n_img_tokens, cfg.d_model),
                                jnp.float32)
    elif cfg.family == "encdec":
        mem = jax.random.normal(k, (b, cfg.n_frames, cfg.d_model),
                                jnp.float32)
    return tokens, mem


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    params, axes = M.init_params(cfg, jax.random.PRNGKey(0))
    assert count_params(params) > 0
    tokens, mem = _batch(cfg)
    logits, aux, _ = M.forward(params, cfg, tokens, memory=mem)
    assert logits.shape == (2, 16, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(logits)))
    # every param leaf got a logical-axes record (sharding coverage)
    n_leaves = len(jax.tree.leaves(params))
    assert len(axes) > 0 and n_leaves > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_step(arch):
    """One SGD step moves the loss; gradients finite."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(1))
    tokens, mem = _batch(cfg, s=17)

    def loss_fn(p):
        loss, metrics = M.lm_loss(p, cfg, tokens, memory=mem)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.vdot(g, g).real
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    lr = 0.05 / max(1.0, float(gnorm))     # normalized step: always descends
    p2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    loss2 = loss_fn(p2)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Decode path correctness: prefill S tokens then decode token S must
    reproduce the full-forward logits at position S (same inputs)."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(2))
    b, s = 2, 12
    tokens, mem = _batch(cfg, b=b, s=s + 1, key=3)

    full_logits, _, _ = M.forward(params, cfg, tokens, memory=mem)

    _, cc = E.prefill(params, cfg, tokens[:, :s], cache_len=32, memory=mem)
    step_logits, cc2 = E.decode_step(params, cfg, cc, tokens[:, s:s + 1])
    assert int(cc2["pos"]) == s + 1

    got = np.asarray(step_logits[:, 0])
    want = np.asarray(full_logits[:, s])
    tol = 2e-2 if cfg.family in ("ssm", "hybrid") else 1e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_swa_ring_cache_is_window_bounded():
    cfg = get_config("starcoder2-7b", smoke=True)    # sliding_window=16
    from repro.serving.cache import init_cache
    cc = init_cache(cfg, batch=2, cache_len=1024)
    assert cc["k"].shape[2] == 16                    # ring = window, not 1024


def test_ssm_cache_is_o1():
    cfg = get_config("mamba2-2.7b", smoke=True)
    from repro.serving.cache import init_cache
    cc = init_cache(cfg, batch=2, cache_len=1 << 19)
    leaves = jax.tree.leaves(cc)
    total = sum(int(np.prod(x.shape)) for x in leaves)
    assert total < 1e6                               # no 500k-sized tensor


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_defined_for_all_cells(arch):
    cfg = get_config(arch)                           # FULL config, no alloc
    for cell in SHAPE_CELLS:
        specs = input_specs(cfg, cell)
        assert specs, (arch, cell.name)
        for leaf in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_generate_greedy_runs():
    cfg = get_config("qwen3-1.7b", smoke=True)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(4))
    prompt = jnp.ones((2, 5), jnp.int32)
    toks, cc = E.generate(params, cfg, prompt, n_new=4, cache_len=32)
    assert toks.shape == (2, 4)
    assert int(cc["pos"]) == 5 + 4
