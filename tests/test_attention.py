"""Attention-path equivalence: banded SWA fast path == blocked/flash ==
plain masked softmax, across window/shape edge cases."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import banded_attention, blocked_attention


def plain_attention(q, k, v, q_pos, kv_pos, *, causal, window):
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = (q * (1 / math.sqrt(d))).reshape(b, s, kh, g, d)
    sc = jnp.einsum("bskgd,btkd->bskgt", qf, k,
                    preferred_element_type=jnp.float32)
    msk = jnp.ones((b, s, 1, 1, k.shape[1]), bool)
    if causal:
        msk &= kv_pos[:, None, None, None, :] <= \
            q_pos[:, :, None, None, None]
    if window is not None:
        msk &= kv_pos[:, None, None, None, :] > \
            q_pos[:, :, None, None, None] - window
    sc = jnp.where(msk, sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, s, h, d).astype(q.dtype)


def _mk(b, s, h, kh, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("s,window,block_q", [
    (64, 16, 16), (100, 16, 32), (128, 128, 32),   # window >= seq edge
    (96, 24, 64), (256, 32, 512),                  # block_q > seq edge
])
def test_banded_equals_plain(s, window, block_q):
    q, k, v, pos = _mk(2, s, 4, 2, 16)
    want = plain_attention(q, k, v, pos, pos, causal=True, window=window)
    got = banded_attention(q, k, v, pos, pos, window=window,
                           block_q=block_q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                           (False, None)])
def test_blocked_equals_plain(causal, window):
    q, k, v, pos = _mk(2, 48, 4, 4, 8, seed=1)
    want = plain_attention(q, k, v, pos, pos, causal=causal, window=window)
    got = blocked_attention(q, k, v, pos, pos, causal=causal,
                            window=window, block_kv=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_banded_grads_match_blocked():
    q, k, v, pos = _mk(1, 64, 2, 2, 8, seed=2)

    def loss_banded(q, k, v):
        return jnp.sum(banded_attention(q, k, v, pos, pos, window=16,
                                        block_q=16) ** 2)

    def loss_blocked(q, k, v):
        return jnp.sum(blocked_attention(q, k, v, pos, pos, causal=True,
                                         window=16, block_kv=16) ** 2)

    g1 = jax.grad(loss_banded, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_blocked, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_banded_config_path_in_model():
    """starcoder2 smoke with banded_attention=True matches the default."""
    from repro.configs.registry import get_config
    from repro.models import model as M
    cfg0 = get_config("starcoder2-7b", smoke=True)
    cfg1 = cfg0.scaled(banded_attention=True, attn_block_q=8)
    params, _ = M.init_params(cfg0, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, cfg0.vocab)
    l0, _, _ = M.forward(params, cfg0, tok)
    l1, _, _ = M.forward(params, cfg1, tok)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-4, atol=2e-4)
