"""Distributed halo-exchange sharding (`distributed/halo.py`) + the mesh
context and plan-key threading around it.

Most of the real multi-device coverage needs virtual devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), which must be
set before jax initializes — the main tier-1 suite deliberately runs on
the single real CPU device (see conftest.py), so those tests skip here
and run for real in CI's ``distributed`` job.  One subprocess smoke test
keeps tier-1 exercising the true multi-device path.
"""
import os
import subprocess
import sys
import textwrap
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import StencilEngine
from repro.core.stencil import make_stencil
from repro.distributed.halo import ShardedStencilEngine, grid_mesh
from repro.distributed.sharding import (active_mesh_rules, constrain,
                                        default_rules, use_mesh_rules)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DC = jax.device_count()


def needs(n):
    return pytest.mark.skipif(
        DC < n, reason=f"needs {n} devices (CI distributed job forces 8 "
                       f"virtual CPU devices; this session has {DC})")


def _interior_sizes(parts, h):
    """Non-divisible interior extents satisfying block > 2h per axis."""
    n0 = parts[0] * (2 * h + 1) + 3
    n1 = (parts[1] if len(parts) > 1 else 1) * (2 * h + 1) + 5
    return max(n0, 21), max(n1, 17)


# ---------------------------------------------------------------------------
# engine correctness vs the single-device direct oracle
# ---------------------------------------------------------------------------

@needs(8)
@pytest.mark.parametrize("shape_kind", ["box", "star"])
@pytest.mark.parametrize("radius", [1, 2])
@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("parts", [(8,), (4, 2)], ids=["mesh1d", "mesh2d"])
def test_sharded_matches_direct_oracle(shape_kind, radius, k, parts, rng):
    """The acceptance sweep: radius {1,2} × 1-D/2-D mesh × box/star ×
    temporal_steps {1,2}, on non-divisible shapes (padding path)."""
    h = k * radius
    n0, n1 = _interior_sizes(parts, h)
    spec = make_stencil(shape_kind, 2, radius, seed=3)
    ref = StencilEngine(spec, backend="direct", temporal_steps=k)
    eng = ShardedStencilEngine(spec, grid_mesh(parts), temporal_steps=k)
    assert eng.n_shards == 8
    # halo-inclusive call convention (matches StencilEngine.__call__)
    x = jnp.asarray(rng.normal(size=(n0 + 2 * h, n1 + 2 * h)), jnp.float32)
    np.testing.assert_allclose(eng(x), ref(x), rtol=1e-5, atol=1e-5)
    # device-resident iterate == zero-re-pad iterate, center-cropped
    u = jnp.asarray(rng.normal(size=(n0, n1)), jnp.float32)
    got = eng.iterate(u, 2 * k)
    want = ref.iterate(jnp.pad(u, h), 2 * k)[h:-h, h:-h]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@needs(8)
@pytest.mark.parametrize("backend", ["gemm", "sptc"])
def test_sharded_matrix_backends_match_oracle(backend, rng):
    """Per-shard body is the same emit(plan) lowering: the matrix
    backends run unchanged inside shard_map."""
    spec = make_stencil("box", 2, 2, seed=3)
    ref = StencilEngine(spec, backend="direct")
    x = jnp.asarray(rng.normal(size=(44, 52)), jnp.float32)
    for fuse in (False, True):
        eng = ShardedStencilEngine(spec, grid_mesh((4, 2)),
                                   backend=backend, fuse_rows=fuse)
        np.testing.assert_allclose(eng(x), ref(x), rtol=1e-4, atol=1e-4)


@needs(8)
def test_sharded_1d_grid(rng):
    spec = make_stencil("star", 1, 2, seed=3)
    ref = StencilEngine(spec, backend="direct")
    eng = ShardedStencilEngine(spec, grid_mesh(8))
    x = jnp.asarray(rng.normal(size=(103,)), jnp.float32)
    np.testing.assert_allclose(eng(x), ref(x), rtol=1e-5, atol=1e-5)


def test_degenerate_single_device_mesh_matches(rng):
    """A 1-device mesh is valid everywhere (no exchange, plain zero pad)
    and must agree with the plain engine — runs in tier-1."""
    spec = make_stencil("box", 2, 1, seed=3)
    ref = StencilEngine(spec, backend="direct")
    eng = ShardedStencilEngine(spec, grid_mesh(1))
    assert eng.n_shards == 1 and eng.partition() == {}
    x = jnp.asarray(rng.normal(size=(26, 30)), jnp.float32)
    np.testing.assert_allclose(eng(x), ref(x), rtol=1e-5, atol=1e-5)
    u = jnp.asarray(rng.normal(size=(24, 28)), jnp.float32)
    want = ref.iterate(jnp.pad(u, 1), 3)[1:-1, 1:-1]
    np.testing.assert_allclose(eng.iterate(u, 3), want,
                               rtol=1e-5, atol=1e-5)


@needs(2)
def test_block_too_small_raises():
    spec = make_stencil("box", 2, 2, seed=3)
    eng = ShardedStencilEngine(spec, grid_mesh(2), temporal_steps=2)
    with pytest.raises(ValueError, match="blocks > 2·k·r"):
        eng.step(jnp.zeros((14, 20), jnp.float32))   # blocks of 7 <= 8


def test_mesh_validation():
    spec2 = make_stencil("box", 2, 1, seed=3)
    spec1 = make_stencil("star", 1, 1, seed=3)
    with pytest.raises(ValueError, match="needs"):
        grid_mesh(10_000)
    with pytest.raises(ValueError, match="only 1-D"):
        ShardedStencilEngine(spec1, grid_mesh((1, 1)))
    with pytest.raises(ValueError, match="distinct axes"):
        ShardedStencilEngine(spec2, grid_mesh(1), grid_axes=(5,))


@needs(8)
def test_sharded_batched_vmap(rng):
    """vmap over the sharded engine: every job mesh-partitioned, batch
    axis unsharded — the serving super-batch path."""
    spec = make_stencil("star", 2, 1, seed=3)
    ref = StencilEngine(spec, backend="direct")
    eng = ShardedStencilEngine(spec, grid_mesh((4, 2)))
    xs = jnp.asarray(rng.normal(size=(5, 42, 34)), jnp.float32)
    ys = jax.jit(jax.vmap(eng._fn))(xs)
    np.testing.assert_allclose(ys, jax.vmap(ref._fn)(xs),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# vet: collective budget on the sharded hot path
# ---------------------------------------------------------------------------

def test_vet_sharded_probe():
    """>= 2 devices: the step/iterate hot path lowers with exactly 2
    collective-permutes per partitioned axis and nothing gather-shaped.
    Single device: the analyzer skips cleanly (no probes, no findings)."""
    from repro.vet.config import VetConfig
    from repro.vet.lowering import analyze_sharded
    findings, probes = analyze_sharded(VetConfig())
    assert findings == []
    if DC < 2:
        assert probes == {}
    else:
        assert probes
        for symbol, counts in probes.items():
            assert counts["gather-like"] == 0, symbol
            expected = 4 if "mesh2x2" in symbol else 2
            assert counts["collective-permute"] == expected, symbol


# ---------------------------------------------------------------------------
# tuner + serving threading
# ---------------------------------------------------------------------------

@needs(8)
def test_tuned_apply_with_mesh(tmp_path, rng):
    from repro.tuner.api import tuned_apply
    from repro.tuner.cache import PlanCache
    spec = make_stencil("box", 2, 1, seed=4)
    ref = StencilEngine(spec, backend="direct")
    cache = PlanCache(path=tmp_path / "plans.json")
    x = jnp.asarray(rng.normal(size=(42, 34)), jnp.float32)
    y = tuned_apply(spec, x, cache=cache, mode="cost", mesh=(4, 2))
    np.testing.assert_allclose(y, ref(x), rtol=1e-4, atol=1e-4)
    # sharded and single-device plans landed in distinct cache entries
    y1 = tuned_apply(spec, x, cache=cache, mode="cost")
    np.testing.assert_allclose(y1, ref(x), rtol=1e-4, atol=1e-4)
    meshes = sorted({k.split("mesh=")[-1] for k in cache._plans})
    assert meshes == ["1", "4x2"]


@needs(8)
def test_stencil_driver_with_mesh(rng):
    from repro.serving.stencil_driver import StencilDriver
    from repro.tuner.cache import PlanCache
    spec = make_stencil("star", 2, 1, seed=4)
    ref = StencilEngine(spec, backend="direct")
    jobs = [jnp.asarray(rng.normal(size=(42, 34)), jnp.float32)
            for _ in range(4)]
    with StencilDriver(cache=PlanCache(), mode="cost",
                       mesh=grid_mesh((4, 2))) as driver:
        key = driver.group_key(spec, jobs[0])
        assert "mesh=4x2" in key
        results = driver.map([(spec, x) for x in jobs])
    for x, y in zip(jobs, results):
        np.testing.assert_allclose(y, ref(x), rtol=1e-4, atol=1e-4)


def test_driver_mesh_changes_bucket():
    """Sharded jobs must never co-batch with single-device jobs: the
    group key carries the mesh geometry (key-level; no devices needed)."""
    from repro.tuner.api import batch_group_key
    spec = make_stencil("box", 2, 1, seed=4)
    plain = batch_group_key(spec, (34, 34), jnp.float32)
    sharded = batch_group_key(spec, (34, 34), jnp.float32, mesh="4x2")
    assert plain != sharded
    assert plain.endswith("mesh=1") and sharded.endswith("mesh=4x2")
    # a degenerate all-1 mesh IS single-device and shares the bucket
    assert batch_group_key(spec, (34, 34), jnp.float32,
                           mesh=(1, 1)) == plain


# ---------------------------------------------------------------------------
# use_mesh_rules thread visibility (the serving worker-thread bugfix)
# ---------------------------------------------------------------------------

def _one_device_mesh():
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1], dtype=object), ("data",))


def test_use_mesh_rules_visible_across_threads():
    """constrain() used to silently no-op on any thread but the one that
    entered the context — exactly where BatchScheduler executes batches."""
    mesh, rules = _one_device_mesh(), default_rules()
    seen = {}

    def worker():
        seen["state"] = active_mesh_rules()
        # must not raise: the constraint resolves against the mesh
        seen["y"] = constrain(jnp.ones((4, 8)), ("batch", None))

    with use_mesh_rules(mesh, rules):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["state"] == (mesh, rules)
    assert seen["y"].shape == (4, 8)
    assert active_mesh_rules() is None          # context fully unwound


def test_use_mesh_rules_thread_local_override():
    """A thread may nest its own context over the process default; other
    threads keep seeing the default, and process_default=False restores
    the old thread-confined behavior."""
    mesh, rules = _one_device_mesh(), default_rules()
    override_rules = default_rules(fsdp=False)
    seen = {}

    def worker():
        with use_mesh_rules(mesh, override_rules, process_default=False):
            seen["inside"] = active_mesh_rules()
        seen["after"] = active_mesh_rules()

    with use_mesh_rules(mesh, rules):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert active_mesh_rules() == (mesh, rules)   # main thread intact
    assert seen["inside"] == (mesh, override_rules)
    assert seen["after"] == (mesh, rules)             # falls back to default


# ---------------------------------------------------------------------------
# tier-1 subprocess smoke: the true multi-device path
# ---------------------------------------------------------------------------

def test_multidevice_smoke_subprocess():
    """Real 4-virtual-device run (flag must precede jax init, so it
    cannot share this process): sharded == oracle, 2 ppermutes/axis."""
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, re
        assert jax.device_count() == 4, jax.device_count()
        from repro.core.engine import StencilEngine
        from repro.core.stencil import make_stencil
        from repro.distributed.halo import ShardedStencilEngine, grid_mesh
        spec = make_stencil("box", 2, 1, seed=3)
        eng = ShardedStencilEngine(spec, grid_mesh((2, 2)))
        ref = StencilEngine(spec, backend="direct")
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(25, 23)).astype(np.float32))
        np.testing.assert_allclose(eng(x), ref(x), rtol=1e-5, atol=1e-5)
        text = jax.jit(eng._run_sharded, static_argnums=1).lower(
            jax.ShapeDtypeStruct((24, 24), jnp.float32), 1
            ).compile().as_text()
        cp = len(re.findall(r"collective-permute(?:-start)?\\(", text))
        ag = len(re.findall(r"all-(?:gather|reduce|to-all)", text))
        assert cp == 4 and ag == 0, (cp, ag)
        print("SMOKE-OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = subprocess.run([sys.executable, "-c", script], env=env, cwd=REPO,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "SMOKE-OK" in r.stdout, (
        r.stdout[-2000:] + r.stderr[-2000:])
