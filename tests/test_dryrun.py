"""Dry-run harness smoke: one real (arch x cell x mesh) lowering+compile in
a subprocess (the 512-device XLA flag must be set before jax init, so this
cannot run in-process with the rest of the suite)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_single_cell_compiles(tmp_path):
    out = tmp_path / "cell.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-1.7b", "--cell", "decode_32k",
         "--out", str(out), "--no-resume"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["ok"] and rec["mesh"] == "16x16" and rec["chips"] == 256
    # roofline terms present and sane
    assert rec["t_memory_s"] > 0 and rec["hlo_gflops"] > 0
    assert rec["per_device_gb"] < 16, "decode cell must fit v5e HBM"


@pytest.mark.slow
def test_dryrun_multipod_cell_compiles(tmp_path):
    out = tmp_path / "cell.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-2.7b", "--cell", "long_500k",
         "--multi-pod", "--out", str(out), "--no-resume"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["ok"] and rec["chips"] == 512
    # O(1) SSM state: the 500k-context decode cache must be tiny
    assert rec["per_device_gb"] < 2
