"""StencilEngine backend-equivalence tests: every backend must compute the
same stencil as the direct shifted-FMA oracle, across the paper's suite."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import StencilEngine, apply_stencil
from repro.core.stencil import make_stencil, paper_suite, star_mask
from repro.core.sptc import sptc_matmul, swap_rows
from repro.core.sparsify import sparsify_stencil_kernel
from repro.core.transform import kernel_matrix


def _ref(spec, x):
    """numpy oracle: dense correlation with the stencil weights."""
    r, d = spec.radius, spec.ndim
    w = spec.weights
    out_shape = tuple(s - 2 * r for s in x.shape)
    out = np.zeros(out_shape)
    for off in np.ndindex(*w.shape):
        if w[off] == 0:
            continue
        sl = tuple(slice(o, o + n) for o, n in zip(off, out_shape))
        out += w[off] * x[sl]
    return out


BACKENDS = ["gemm", "sptc"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("shape,ndim,r", [
    ("box", 1, 1), ("box", 1, 2), ("star", 2, 1), ("star", 2, 3),
    ("box", 2, 1), ("box", 2, 2), ("box", 2, 3), ("box", 3, 1),
    ("star", 3, 2),
])
def test_backends_match_direct(backend, shape, ndim, r, rng):
    spec = make_stencil(shape, ndim, r, seed=11)
    dims = {1: (203,), 2: (37, 41), 3: (13, 15, 17)}[ndim]
    x = rng.normal(size=tuple(s + 2 * r for s in dims)).astype(np.float32)
    want = _ref(spec, x)
    got = apply_stencil(spec, jnp.asarray(x), backend=backend)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape,ndim,r", [("box", 2, 2), ("star", 2, 2)])
def test_direct_backend_matches_numpy(shape, ndim, r, rng):
    spec = make_stencil(shape, ndim, r, seed=7)
    x = rng.normal(size=(40 + 2 * r, 52 + 2 * r)).astype(np.float32)
    got = apply_stencil(spec, jnp.asarray(x), backend="direct")
    np.testing.assert_allclose(np.asarray(got), _ref(spec, x),
                               rtol=2e-5, atol=2e-5)


def test_engine_iterate_stable(rng):
    """Iterated smoothing stencil stays bounded (weights sum to 1)."""
    spec = make_stencil("box", 2, 1, seed=0)
    eng = StencilEngine(spec, backend="direct")
    x = jnp.asarray(rng.uniform(0, 1, size=(34, 34)).astype(np.float32))
    y = eng.iterate(x, steps=10)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(jnp.max(jnp.abs(y))) <= 1.0 + 1e-4


def test_nonsquare_kernel_matrix_beats_tcstencil():
    """Paper §3.2.1: rectangular K (L x 2r+L) has no blank rows — every row
    holds a full kernel copy (TCStencil's square L x L wastes 2r rows)."""
    for r in (1, 2, 3):
        K = kernel_matrix(np.ones(2 * r + 1), pad_width=False)
        assert np.all((K != 0).sum(axis=1) == 2 * r + 1)


def test_sptc_matmul_equals_dense(rng):
    """Simulated mma.sp == dense matmul with the permuted banded matrix."""
    for r in (1, 2, 3, 5):
        w = rng.normal(size=2 * r + 1)
        sk = sparsify_stencil_kernel(w)
        K = kernel_matrix(w, L=sk.L, pad_width=True)
        x = rng.normal(size=(2 * sk.L, 19)).astype(np.float32)
        got = sptc_matmul(jnp.asarray(sk.values, jnp.float32),
                          jnp.asarray(sk.meta), jnp.asarray(x[sk.perm]))
        np.testing.assert_allclose(np.asarray(got), K @ x, rtol=2e-5,
                                   atol=1e-5)


def test_swap_rows_reference():
    x = np.arange(8.0)[:, None] * np.ones((1, 3))
    perm = np.array([0, 5, 2, 7, 4, 1, 6, 3])
    np.testing.assert_array_equal(np.asarray(swap_rows(jnp.asarray(x), perm)),
                                  x[perm])


@pytest.mark.parametrize("backend", ["direct", "gemm", "sptc"])
def test_bf16_inputs(backend, rng):
    spec = make_stencil("box", 2, 1, seed=2)
    x = rng.normal(size=(20, 24)).astype(np.float32)
    xb = jnp.asarray(x, jnp.bfloat16)
    got = apply_stencil(spec, xb, backend=backend)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               _ref(spec, x)[:, :], rtol=5e-2, atol=5e-2)


def test_paper_suite_all_runs():
    for spec in paper_suite():
        dims = {1: (130,), 2: (18, 22)}[spec.ndim]
        x = jnp.ones(tuple(s + 2 * spec.radius for s in dims))
        y = apply_stencil(spec, x, backend="sptc")
        assert y.shape == dims
        # smoothing kernel of all-ones input -> all ones out
        np.testing.assert_allclose(np.asarray(y), 1.0, rtol=1e-5)


@pytest.mark.parametrize("backend", ["gemm", "sptc"])
@pytest.mark.parametrize("shape,r", [("box", 1), ("box", 2), ("box", 3)])
def test_fused_rows_matches_unfused(backend, shape, r, rng):
    """§Perf D fused execution: one stacked GEMM == per-row application."""
    spec = make_stencil(shape, 2, r, seed=4)
    x = jnp.asarray(rng.normal(size=(41 + 2 * r, 57 + 2 * r)), jnp.float32)
    want = StencilEngine(spec, backend=backend)(x)
    got = StencilEngine(spec, backend=backend, fuse_rows=True)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# variable coefficients: per-point weights through ONE shared 2:4 pattern
# ---------------------------------------------------------------------------

VAR_SWEEP = [("box", 1, 1), ("box", 1, 2), ("box", 1, 3),
             ("star", 2, 1), ("star", 2, 2), ("star", 2, 3),
             ("box", 2, 1), ("box", 2, 2), ("box", 2, 3)]


def _rand_coefficients(spec, out_shape, rng):
    """Random per-output-point kernel field, star cross honored."""
    taps = 2 * spec.radius + 1
    c = rng.normal(size=out_shape + (taps,) * spec.ndim)
    if spec.shape == "star":
        c[..., ~star_mask(spec.ndim, spec.radius)] = 0.0
    return c


def _var_ref(spec, x, c):
    """numpy oracle: out[i] = sum_off c[i, off] * x[i + off]."""
    r, d = spec.radius, spec.ndim
    out_shape = tuple(s - 2 * r for s in x.shape)
    out = np.zeros(out_shape)
    for off in np.ndindex(*(2 * r + 1,) * d):
        sl = tuple(slice(o, o + n) for o, n in zip(off, out_shape))
        out += c[(slice(None),) * d + off] * x[sl]
    return out


@pytest.mark.parametrize("shape,ndim,r", VAR_SWEEP)
def test_variable_coefficients_match_oracle(shape, ndim, r, rng):
    """Radius sweep: every var-coeff backend == the per-point numpy oracle."""
    spec = make_stencil(shape, ndim, r, seed=13)
    dims = {1: (53,), 2: (13, 17)}[ndim]
    c = _rand_coefficients(spec, dims, rng)
    x = rng.normal(size=tuple(s + 2 * r for s in dims)).astype(np.float32)
    want = _var_ref(spec, x, c)
    for backend in ("direct", "gemm", "sptc"):
        eng = StencilEngine(spec, backend=backend, coefficients=c)
        got = np.asarray(eng(jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{backend} {spec.name}")


def test_variable_coefficients_reduce_to_constant(rng):
    """A field replicating the spec's weights == the constant-kernel path."""
    spec = make_stencil("star", 2, 2, seed=5)
    dims = (12, 15)
    c = np.broadcast_to(spec.weights, dims + spec.weights.shape).copy()
    x = jnp.asarray(rng.normal(size=(16, 19)), jnp.float32)
    want = apply_stencil(spec, x, backend="direct")
    got = StencilEngine(spec, backend="sptc", coefficients=c)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_variable_coefficient_engine_is_fixed_shape(rng):
    spec = make_stencil("box", 2, 1, seed=3)
    c = _rand_coefficients(spec, (10, 12), rng)
    eng = StencilEngine(spec, backend="sptc", coefficients=c)
    assert eng.plan_ir.sparsify.shared_pattern
    eng(jnp.zeros((12, 14)))                     # the field's shape: fine
    with pytest.raises(ValueError, match="fixed-shape"):
        eng(jnp.zeros((13, 14)))


# ---------------------------------------------------------------------------
# temporal blocking: k fused steps in one compiled program
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("shape,ndim,r", VAR_SWEEP)
def test_temporal_block_matches_repeated_oracle(shape, ndim, r, k, rng):
    """A k-step engine on a k·r-halo input == k repeated oracle sweeps."""
    spec = make_stencil(shape, ndim, r, seed=17)
    dims = {1: (45,), 2: (11, 13)}[ndim]
    x = rng.normal(size=tuple(s + 2 * k * r for s in dims)).astype(np.float32)
    want = x
    for _ in range(k):
        want = _ref(spec, want)
    for backend in ("direct", "gemm", "sptc"):
        eng = StencilEngine(spec, backend=backend, temporal_steps=k)
        got = np.asarray(eng(jnp.asarray(x)))
        assert got.shape == dims
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{backend} {spec.name} k={k}")


def test_temporal_iterate_matches_blockwise_reference(rng):
    """iterate() with temporal_steps=k: k raw applications per re-pad block."""
    spec = make_stencil("star", 2, 1, seed=0)
    k, steps = 2, 4
    x = rng.uniform(0, 1, size=(20, 22)).astype(np.float32)
    eng = StencilEngine(spec, backend="gemm", temporal_steps=k)
    got = np.asarray(eng.iterate(jnp.asarray(x), steps=steps))
    y = x
    for _ in range(steps // k):
        t = y
        for _ in range(k):
            t = _ref(spec, t)
        y = np.pad(t, k * spec.radius)
    np.testing.assert_allclose(got, y, rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="multiple"):
        eng.iterate(jnp.asarray(x), steps=3)
