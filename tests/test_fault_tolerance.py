"""Fault-tolerance integration: kill/resume mid-training reproduces the
uninterrupted run bit-for-bit; elastic restore re-shards to a new mesh."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.training import (TrainConfig, TrainState, checkpoint as ckpt,
                            data, make_train_step, optimizer as O)
from repro.training.train_step import init_state


def _run(cfg, tc, dc, state, start, stop, ckpt_dir=None, every=2):
    step_fn = jax.jit(make_train_step(cfg, tc))
    losses = {}
    for s in range(start, stop):
        tok = jnp.asarray(data.global_batch(dc, s))
        state, m = step_fn(state, tok)
        losses[s] = float(m["loss"])
        if ckpt_dir and (s + 1) % every == 0:
            ckpt.save(ckpt_dir, s + 1, state.tree(), extra={"step": s + 1})
    return state, losses


@pytest.fixture
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    tc = TrainConfig(opt=O.OptConfig(lr=1e-3, warmup_steps=2,
                                     total_steps=10))
    dc = data.DataConfig(vocab=cfg.vocab, seq_len=12, global_batch=4, seed=5)
    return cfg, tc, dc


def test_kill_and_resume_is_bit_identical(setup, tmp_path):
    cfg, tc, dc = setup
    d = str(tmp_path / "ck")

    # uninterrupted run: 6 steps
    st0, _ = init_state(cfg, jax.random.PRNGKey(0))
    ref_state, ref_losses = _run(cfg, tc, dc, st0, 0, 6)

    # interrupted run: 4 steps with checkpoints, "crash", restore, 2 more
    st1, _ = init_state(cfg, jax.random.PRNGKey(0))
    _, l1 = _run(cfg, tc, dc, st1, 0, 4, ckpt_dir=d, every=2)
    del st1                                        # the crash

    st2, _ = init_state(cfg, jax.random.PRNGKey(0))  # fresh process
    tree, extra = ckpt.restore(d, st2.tree())
    st2 = TrainState(params=tree["params"], opt=O.OptState(**tree["opt"]))
    assert extra["step"] == 4
    st2, l2 = _run(cfg, tc, dc, st2, extra["step"], 6)

    for s in (4, 5):
        np.testing.assert_allclose(l2[s], ref_losses[s], rtol=1e-6)
    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_changes_sharding(setup, tmp_path):
    """Restore the same checkpoint under a different mesh layout — the
    elastic N->M path (single host: 1-device meshes with different specs)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    cfg, tc, dc = setup
    d = str(tmp_path / "ck")
    st, _ = init_state(cfg, jax.random.PRNGKey(0))
    ckpt.save(d, 1, st.tree(), extra={"step": 1})

    mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st.tree())
    tree, _ = ckpt.restore(d, st.tree(), shardings=sh)
    leaf = jax.tree.leaves(tree)[0]
    assert isinstance(leaf.sharding, NamedSharding)
    for a, b in zip(jax.tree.leaves(st.tree()), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_survives_remesh(setup):
    """The step-seeded pipeline gives the SAME global batch regardless of
    how many shards consume it (elastic DP)."""
    _, _, dc = setup
    full = data.global_batch(dc, 7)
    # simulate 2-way and 4-way DP consumers slicing the same batch
    for ways in (2, 4):
        shards = [full[i::1][j * (4 // ways):(j + 1) * (4 // ways)]
                  for j in range(ways) for i in [0]]
        np.testing.assert_array_equal(np.concatenate(shards), full)


def test_train_launcher_resumes(tmp_path):
    """End-to-end: launch/train.py --ckpt-dir resumes after restart."""
    from repro.launch import train as T
    d = str(tmp_path / "run")
    argv = ["--arch", "qwen3-1.7b", "--smoke", "--steps", "6",
            "--batch", "2", "--seq", "16", "--ckpt-dir", d,
            "--ckpt-every", "2", "--log-every", "100"]
    T.main(argv)
    assert ckpt.latest_step(d) == 6
    # "restart": runs 0 extra steps but exercises the restore path
    T.main(argv)
