"""LoweredPlan IR: stage structure, validation, and lower_spec's pipeline.

The tentpole contract of the lowering refactor: `lower_spec` produces an
explicit, ordered, inspectable plan; the engine merely executes it.  These
tests pin the stage sequences per backend family, the table shapes, the
structural validator, and the restrictions on variable-coefficient /
temporal-blocked plans.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import ir
from repro.core.stencil import make_stencil, star_mask
from repro.core.transform import (lower_spec, validate_coefficients)


def _coeff(spec, out_shape, seed=0):
    rng = np.random.default_rng(seed)
    taps = 2 * spec.radius + 1
    c = rng.normal(size=out_shape + (taps,) * spec.ndim)
    if spec.shape == "star":
        c[..., ~star_mask(spec.ndim, spec.radius)] = 0.0
    return c


# ---------------------------------------------------------------------------
# stage sequences per backend family
# ---------------------------------------------------------------------------

def test_stage_sequence_per_backend_family():
    spec = make_stencil("star", 2, 2, seed=0)
    assert lower_spec(spec, "direct").stage_names() == (
        "row-decompose", "emit")
    assert lower_spec(spec, "gemm").stage_names() == (
        "row-decompose", "kernel-matrix", "gather-schedule", "emit")
    assert lower_spec(spec, "sptc").stage_names() == (
        "row-decompose", "kernel-matrix", "strided-swap",
        "gather-schedule", "emit")


def test_stage_names_follow_canonical_order():
    for backend in ("direct", "gemm", "sptc"):
        plan = lower_spec(make_stencil("box", 2, 1, seed=1), backend)
        idx = [ir.STAGE_ORDER.index(n) for n in plan.stage_names()]
        assert idx == sorted(idx)
        assert plan.stage_names()[0] == "row-decompose"
        assert plan.stage_names()[-1] == "emit"


@pytest.mark.parametrize("shape,ndim,r,mode,n_ops", [
    ("box", 1, 2, "single", 1),
    ("star", 2, 1, "star-axis", 2),
    ("star", 3, 2, "star-axis", 3),
    ("box", 2, 1, "rows", 3),
])
def test_decompose_mode_and_op_count(shape, ndim, r, mode, n_ops):
    plan = lower_spec(make_stencil(shape, ndim, r, seed=2), "sptc")
    assert plan.decompose.mode == mode
    assert len(plan.decompose.ops) == n_ops
    assert plan.n_applications() == n_ops
    # downstream tables are per-operand
    assert len(plan.kernel.matrices) == n_ops
    assert len(plan.sparsify.operands) == n_ops
    assert len(plan.gather.slots) == n_ops


def test_fused_rows_mode_and_single_application():
    spec = make_stencil("box", 2, 1, seed=3)
    plan = lower_spec(spec, "sptc", fuse_rows=True)
    assert plan.decompose.mode == "fused-rows"
    assert len(plan.decompose.ops) == 3
    assert plan.n_applications() == 1          # one stacked GEMM
    # the fused window gather carries the swap permutation (§3.3)
    np.testing.assert_array_equal(plan.gather.window, plan.sparsify.perm)


def test_unfused_window_is_identity():
    plan = lower_spec(make_stencil("box", 2, 1, seed=3), "sptc")
    np.testing.assert_array_equal(plan.gather.window,
                                  np.arange(2 * plan.L))


# ---------------------------------------------------------------------------
# table structure
# ---------------------------------------------------------------------------

def test_matrix_and_schedule_shapes():
    spec = make_stencil("box", 1, 2, seed=4)
    plan = lower_spec(spec, "sptc")
    L = plan.L
    assert L == 2 * spec.radius + 2
    (mat,) = plan.kernel.matrices
    assert mat.shape == (L, 2 * L)
    (sp24,) = plan.sparsify.operands
    assert sp24.values.shape == (L, L)          # K/2 = 2L/2 = L slots
    (slots,) = plan.gather.slots
    (taps,) = plan.gather.taps
    assert slots.shape == taps.shape == (L, L)
    assert slots.min() >= 0 and slots.max() < 2 * L


def test_tap_table_masks_off_band():
    # row i, slot column j: tap = j - i inside [0, taps), else -1
    slots = np.tile(np.arange(6), (3, 1))
    t = ir.tap_table(slots, taps=3)
    assert t.shape == (3, 6)
    assert t[0, 0] == 0 and t[0, 2] == 2 and t[0, 3] == -1
    assert t[2, 1] == -1 and t[2, 2] == 0 and t[2, 4] == 2
    assert np.all((t == -1) | ((t >= 0) & (t < 3)))


def test_sparsify_stage_perm_is_involution():
    plan = lower_spec(make_stencil("star", 2, 3, seed=5), "sptc")
    perm = plan.sparsify.perm
    np.testing.assert_array_equal(perm[perm], np.arange(2 * plan.L))


# ---------------------------------------------------------------------------
# variable-coefficient plans
# ---------------------------------------------------------------------------

def test_var_plan_shares_one_pattern():
    spec = make_stencil("box", 2, 1, seed=6)
    c = _coeff(spec, (9, 11))
    plan = lower_spec(spec, "sptc", coefficients=c)
    assert plan.emit.coefficient_mode == "var"
    assert plan.sparsify.shared_pattern
    metas = {op.meta.tobytes() for op in plan.sparsify.operands}
    assert len(metas) == 1                      # ONE 2:4 pattern for all rows
    # one slot/tap schedule works for every operand
    for s in plan.gather.slots[1:]:
        np.testing.assert_array_equal(s, plan.gather.slots[0])
    # structural kernels are the all-ones band
    for k in plan.decompose.kernels:
        np.testing.assert_array_equal(k, np.ones(2 * spec.radius + 1))
    assert len(plan.decompose.coefficients) == len(plan.decompose.ops)


def test_var_plan_restrictions():
    spec = make_stencil("box", 2, 1, seed=7)
    c = _coeff(spec, (8, 8))
    with pytest.raises(ValueError, match="jnp backends"):
        lower_spec(spec, "pallas_mxu", coefficients=c)
    with pytest.raises(ValueError, match="temporal"):
        lower_spec(spec, "gemm", coefficients=c, temporal_steps=2)
    with pytest.raises(ValueError, match="fuse_rows"):
        lower_spec(spec, "gemm", coefficients=c, fuse_rows=True)


def test_validate_coefficients_shape_and_star_cross():
    spec = make_stencil("star", 2, 1, seed=8)
    with pytest.raises(ValueError, match="shape"):
        validate_coefficients(spec, np.zeros((8, 8, 3)))
    bad = np.ones((8, 8, 3, 3))                 # corners of a star kernel
    with pytest.raises(ValueError, match="cross"):
        validate_coefficients(spec, bad)
    ok = _coeff(spec, (8, 8))
    np.testing.assert_array_equal(validate_coefficients(spec, ok), ok)


# ---------------------------------------------------------------------------
# temporal blocking + errors + describe
# ---------------------------------------------------------------------------

def test_temporal_steps_is_an_ir_attribute():
    plan = lower_spec(make_stencil("star", 2, 1, seed=9), "sptc",
                      temporal_steps=4)
    assert plan.emit.temporal_steps == 4
    assert "k=4" in plan.describe()
    with pytest.raises(ValueError, match="temporal_steps"):
        lower_spec(make_stencil("star", 2, 1, seed=9), "sptc",
                   temporal_steps=0)


def test_lower_spec_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        lower_spec(make_stencil("box", 1, 1, seed=0), "cuda")


def test_describe_renders_pipeline():
    spec = make_stencil("star", 2, 1, seed=10)
    d = lower_spec(spec, "sptc").describe()
    assert d.startswith(spec.name)
    for name in ("row-decompose[star-axis x2]", "kernel-matrix[L4]",
                 "strided-swap[2:4", "gather-schedule", "emit[sptc]"):
        assert name in d


def test_validate_catches_structural_breakage():
    plan = lower_spec(make_stencil("box", 1, 1, seed=11), "sptc")
    # out-of-order stages
    bad = ir.LoweredPlan(spec=plan.spec, L=plan.L,
                         stages=tuple(reversed(plan.stages)))
    with pytest.raises(ValueError, match="stage order"):
        bad.validate()
    # missing required stage for a sparse backend
    nosp = ir.LoweredPlan(
        spec=plan.spec, L=plan.L,
        stages=tuple(s for s in plan.stages
                     if not isinstance(s, ir.StridedSwapSparsify)))
    with pytest.raises(ValueError, match="strided-swap"):
        nosp.validate()
    # shared_pattern flag lying about differing metadata: the 2-D star's
    # axis kernels have different zero structure, hence different meta
    star = lower_spec(make_stencil("star", 2, 1, seed=11), "sptc")
    sp = star.sparsify
    assert not sp.shared_pattern
    lying = dataclasses.replace(sp, shared_pattern=True)
    stages = tuple(lying if isinstance(s, ir.StridedSwapSparsify) else s
                   for s in star.stages)
    with pytest.raises(ValueError, match="shared_pattern"):
        ir.LoweredPlan(spec=star.spec, L=star.L, stages=stages).validate()


def test_engine_exposes_plan_ir():
    from repro.core.engine import StencilEngine
    spec = make_stencil("box", 2, 2, seed=12)
    eng = StencilEngine(spec, backend="sptc", fuse_rows=True)
    assert eng.plan_ir.emit.backend == "sptc"
    assert eng.plan_ir.emit.fuse_rows
    assert eng.plan_ir.stage_names() == (
        "row-decompose", "kernel-matrix", "strided-swap",
        "gather-schedule", "emit")
