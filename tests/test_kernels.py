"""Per-kernel Pallas (interpret=True) vs ref.py oracle sweeps over
shapes & dtypes, per the kernel deliverable contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsify import sparsify_stencil_kernel
from repro.core.stencil import make_stencil
from repro.core.engine import apply_stencil


DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else \
        dict(rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# sptc_spmm — the faithful simulated-SpTC kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("r,n", [(1, 64), (1, 200), (2, 128), (3, 384),
                                 (5, 96), (7, 513)])
def test_sptc_spmm_vs_ref(r, n, dtype, rng):
    from repro.kernels.sptc_spmm.ops import sptc_spmm
    from repro.kernels.sptc_spmm.ref import sptc_spmm_ref
    sk = sparsify_stencil_kernel(rng.normal(size=2 * r + 1))
    x = jnp.asarray(rng.normal(size=(2 * sk.L, n)), dtype)
    vals = jnp.asarray(sk.values, dtype)
    meta = jnp.asarray(sk.meta)
    got = sptc_spmm(vals, meta, x, interpret=True)
    want = sptc_spmm_ref(vals, meta, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("t", [1, 3, 8])
def test_sptc_spmm_windows_vs_ref(t, rng):
    from repro.kernels.sptc_spmm.ops import sptc_spmm_windows
    from repro.kernels.sptc_spmm.ref import sptc_spmm_windows_ref
    sk = sparsify_stencil_kernel(rng.normal(size=5))        # r = 2
    win = jnp.asarray(rng.normal(size=(t, 2 * sk.L, 130)), jnp.float32)
    vals = jnp.asarray(sk.values, jnp.float32)
    meta = jnp.asarray(sk.meta)
    got = sptc_spmm_windows(vals, meta, win, interpret=True)
    want = sptc_spmm_windows_ref(vals, meta, win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sptc_spmm fused v2 — window DMA + in-kernel swap/gather + MXU, one program
# ---------------------------------------------------------------------------

def _direct_1d(w, x, n_out):
    return np.stack([np.tensordot(w, x[i:i + len(w)], axes=(0, 0))
                     for i in range(n_out)])


@pytest.mark.parametrize("r,c", [(1, 64), (1, 200), (2, 128), (3, 384)])
def test_sptc_fused_general_vs_direct(r, c, rng):
    from repro.kernels.sptc_spmm.ops import sptc_spmm_fused
    w = rng.normal(size=2 * r + 1)
    sk = sparsify_stencil_kernel(w)
    n_out = 3 * sk.L + 2
    x = rng.normal(size=(n_out + 2 * r, c)).astype(np.float32)
    got = sptc_spmm_fused(sk.sparse, sk.perm, jnp.asarray(x), n_out=n_out,
                          L=sk.L, star_fast=False, block_n=256,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), _direct_1d(w, x, n_out),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("r", [1, 2, 3])
def test_sptc_fused_star_fast_path_vs_direct(r, rng):
    """The metadata-free banded path fires for every banded 1-D kernel
    (the swap∘meta gather is the identity band of the taps)."""
    from repro.core.sparsify import contiguous_band_values
    from repro.kernels.sptc_spmm.ops import sptc_spmm_fused
    w = rng.normal(size=2 * r + 1)
    sk = sparsify_stencil_kernel(w)
    assert contiguous_band_values(sk.sparse, sk.perm) is not None
    n_out = 2 * sk.L + 3
    x = rng.normal(size=(n_out + 2 * r, 130)).astype(np.float32)
    got = sptc_spmm_fused(sk.sparse, sk.perm, jnp.asarray(x), n_out=n_out,
                          L=sk.L, star_fast=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), _direct_1d(w, x, n_out),
                               rtol=2e-5, atol=2e-5)


def test_sptc_fused_bf16_accumulates_f32(rng):
    from repro.kernels.sptc_spmm.ops import sptc_spmm_fused
    w = rng.normal(size=5)                                   # r = 2
    sk = sparsify_stencil_kernel(w)
    n_out = 2 * sk.L
    x = rng.normal(size=(n_out + 4, 128)).astype(np.float32)
    got = sptc_spmm_fused(sk.sparse, sk.perm, jnp.asarray(x), n_out=n_out,
                          L=sk.L, compute_dtype="bfloat16", interpret=True)
    assert got.dtype == jnp.float32          # output stays in input dtype
    np.testing.assert_allclose(np.asarray(got), _direct_1d(w, x, n_out),
                               rtol=3e-2, atol=3e-2)


def test_sptc_fused_rejects_non_swap_perm(rng):
    from repro.kernels.sptc_spmm.ops import sptc_spmm_fused
    sk = sparsify_stencil_kernel(rng.normal(size=3))
    x = jnp.asarray(rng.normal(size=(20, 64)), jnp.float32)
    with pytest.raises(ValueError, match="strided-swap"):
        sptc_spmm_fused(sk.sparse, np.arange(2 * sk.L), x, n_out=8, L=sk.L)


# ---------------------------------------------------------------------------
# interpret-mode defaults (all four kernel packages' *_call entry points)
# ---------------------------------------------------------------------------

def test_default_interpret_env_override(monkeypatch):
    from repro.kernels import common
    monkeypatch.delenv(common.INTERPRET_ENV_VAR, raising=False)
    assert common.default_interpret() is True          # CPU container
    monkeypatch.setenv(common.INTERPRET_ENV_VAR, "0")
    assert common.default_interpret() is False
    monkeypatch.setenv(common.INTERPRET_ENV_VAR, "1")
    assert common.default_interpret() is True


def test_all_call_entry_points_default_interpret_to_backend():
    """interpret must default to None (resolved off the device at call
    time), never a hardcoded True that silently slow-paths a real TPU."""
    import inspect
    from repro.kernels.conv1d.kernel import conv1d_causal_call
    from repro.kernels.sptc_spmm.kernel import (sptc_fused_call,
                                                sptc_spmm_call)
    from repro.kernels.stencil_direct.kernel import stencil2d_call
    from repro.kernels.stencil_gemm.kernel import windows_gemm_call
    for fn in (sptc_spmm_call, sptc_fused_call, windows_gemm_call,
               stencil2d_call, conv1d_causal_call):
        sig = inspect.signature(fn)
        assert sig.parameters["interpret"].default is None, fn.__name__


def test_sptc_spmm_call_interpret_none_matches_explicit(rng):
    from repro.kernels.sptc_spmm.kernel import sptc_spmm_call
    sk = sparsify_stencil_kernel(rng.normal(size=3))
    x = jnp.asarray(rng.normal(size=(2 * sk.L, 64)), jnp.float32)
    vals = jnp.asarray(sk.values, jnp.float32)
    meta = jnp.asarray(sk.meta)
    got = sptc_spmm_call(vals, meta, x)                # None -> CPU -> True
    want = sptc_spmm_call(vals, meta, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# dispatch — pallas_direct 3-D builder
# ---------------------------------------------------------------------------

def test_pallas_direct_3d_zero_kernel_returns_zeros(rng):
    """Regression: fn3d returned None when every leading-axis slab was
    all-zero (every slab skipped, accumulator never initialized)."""
    from repro.core.stencil import StencilSpec
    from repro.kernels.dispatch import build
    spec = StencilSpec(shape="box", ndim=3, radius=1,
                       weights=np.zeros((3, 3, 3)))
    fn = build(spec, "pallas_direct", 4)
    x = jnp.asarray(rng.normal(size=(8, 10, 12)), jnp.float32)
    y = fn(x)
    assert y is not None
    assert y.shape == (6, 8, 10) and y.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(y), np.zeros((6, 8, 10)))


# ---------------------------------------------------------------------------
# stencil_gemm — dense windows GEMM (Tensor-Core baseline analogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("l,t,c", [(4, 1, 64), (6, 4, 128), (8, 3, 200),
                                   (16, 2, 512)])
def test_windows_gemm_vs_ref(l, t, c, dtype, rng):
    from repro.kernels.stencil_gemm.ops import windows_gemm
    from repro.kernels.stencil_gemm.ref import windows_gemm_ref
    km = jnp.asarray(rng.normal(size=(l, 2 * l)), dtype)
    win = jnp.asarray(rng.normal(size=(t, 2 * l, c)), dtype)
    got = windows_gemm(km, win, interpret=True)
    want = windows_gemm_ref(km, win)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# stencil_direct — tiled VPU shift-FMA kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,r", [("box", 1), ("box", 2), ("box", 3),
                                     ("star", 2)])
@pytest.mark.parametrize("dims", [(16, 16), (40, 130), (128, 256), (37, 91)])
def test_stencil_direct_2d_vs_ref(shape, r, dims, rng):
    from repro.kernels.stencil_direct.ops import stencil2d
    from repro.kernels.stencil_direct.ref import stencil2d_ref
    spec = make_stencil(shape, 2, r, seed=13)
    x = jnp.asarray(rng.normal(size=(dims[0] + 2 * r, dims[1] + 2 * r)),
                    jnp.float32)
    got = stencil2d(spec.weights, x, interpret=True)
    want = stencil2d_ref(spec.weights, x)
    assert got.shape == dims
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,r", [(100, 1), (1000, 2), (4096, 3)])
def test_stencil_direct_1d_vs_ref(n, r, rng):
    from repro.kernels.stencil_direct.ops import stencil1d
    spec = make_stencil("box", 1, r, seed=3)
    x = rng.normal(size=(n + 2 * r,)).astype(np.float32)
    got = stencil1d(spec.weights, jnp.asarray(x), interpret=True)
    want = np.correlate(x, spec.weights[::-1], mode="valid")[::-1][::-1]
    # np.correlate(x, w) flips nothing for symmetric check; compute directly:
    want = np.array([np.dot(spec.weights, x[i:i + 2 * r + 1])
                     for i in range(n)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_pallas_direct_backend_full_stencils(rng):
    """Whole-engine pallas_direct backend vs direct for 1/2/3-D."""
    for shape, ndim, r in [("box", 2, 1), ("star", 2, 2), ("box", 3, 1)]:
        spec = make_stencil(shape, ndim, r, seed=1)
        dims = {2: (24, 40), 3: (9, 12, 20)}[ndim]
        x = jnp.asarray(
            rng.normal(size=tuple(s + 2 * r for s in dims)), jnp.float32)
        want = apply_stencil(spec, x, backend="direct")
        got = apply_stencil(spec, x, backend="pallas_direct")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_pallas_backends_via_engine(rng):
    """pallas_mxu / pallas_sptc engine paths vs direct on a 2-D box."""
    spec = make_stencil("box", 2, 2, seed=9)
    x = jnp.asarray(rng.normal(size=(36, 52)), jnp.float32)
    want = apply_stencil(spec, x, backend="direct")
    for backend in ("pallas_mxu", "pallas_sptc"):
        got = apply_stencil(spec, x, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5, err_msg=backend)


# ---------------------------------------------------------------------------
# conv1d — depthwise causal conv (the technique's LM integration point)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,t,d,k", [(1, 16, 8, 4), (2, 100, 64, 4),
                                     (3, 257, 128, 4), (1, 32, 200, 2)])
def test_conv1d_vs_ref(b, t, d, k, dtype, rng):
    from repro.kernels.conv1d.ops import conv1d_causal
    from repro.kernels.conv1d.ref import conv1d_causal_ref
    x = jnp.asarray(rng.normal(size=(b, t, d)), dtype)
    w = jnp.asarray(rng.normal(size=(k, d)), dtype)
    got = conv1d_causal(x, w, interpret=True)
    want = conv1d_causal_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_conv1d_causality(rng):
    """Output at t must not depend on inputs after t."""
    from repro.kernels.conv1d.ref import conv1d_causal_ref
    x = jnp.asarray(rng.normal(size=(1, 20, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    y1 = conv1d_causal_ref(x, w)
    x2 = x.at[:, 10:, :].set(999.0)
    y2 = conv1d_causal_ref(x2, w)
    np.testing.assert_array_equal(np.asarray(y1[:, :10]),
                                  np.asarray(y2[:, :10]))
