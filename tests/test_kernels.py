"""Per-kernel Pallas (interpret=True) vs ref.py oracle sweeps over
shapes & dtypes, per the kernel deliverable contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsify import sparsify_stencil_kernel
from repro.core.stencil import make_stencil
from repro.core.engine import apply_stencil


DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else \
        dict(rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# sptc_spmm — the faithful simulated-SpTC kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("r,n", [(1, 64), (1, 200), (2, 128), (3, 384),
                                 (5, 96), (7, 513)])
def test_sptc_spmm_vs_ref(r, n, dtype, rng):
    from repro.kernels.sptc_spmm.ops import sptc_spmm
    from repro.kernels.sptc_spmm.ref import sptc_spmm_ref
    sk = sparsify_stencil_kernel(rng.normal(size=2 * r + 1))
    x = jnp.asarray(rng.normal(size=(2 * sk.L, n)), dtype)
    vals = jnp.asarray(sk.values, dtype)
    meta = jnp.asarray(sk.meta)
    got = sptc_spmm(vals, meta, x, interpret=True)
    want = sptc_spmm_ref(vals, meta, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("t", [1, 3, 8])
def test_sptc_spmm_windows_vs_ref(t, rng):
    from repro.kernels.sptc_spmm.ops import sptc_spmm_windows
    from repro.kernels.sptc_spmm.ref import sptc_spmm_windows_ref
    sk = sparsify_stencil_kernel(rng.normal(size=5))        # r = 2
    win = jnp.asarray(rng.normal(size=(t, 2 * sk.L, 130)), jnp.float32)
    vals = jnp.asarray(sk.values, jnp.float32)
    meta = jnp.asarray(sk.meta)
    got = sptc_spmm_windows(vals, meta, win, interpret=True)
    want = sptc_spmm_windows_ref(vals, meta, win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# stencil_gemm — dense windows GEMM (Tensor-Core baseline analogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("l,t,c", [(4, 1, 64), (6, 4, 128), (8, 3, 200),
                                   (16, 2, 512)])
def test_windows_gemm_vs_ref(l, t, c, dtype, rng):
    from repro.kernels.stencil_gemm.ops import windows_gemm
    from repro.kernels.stencil_gemm.ref import windows_gemm_ref
    km = jnp.asarray(rng.normal(size=(l, 2 * l)), dtype)
    win = jnp.asarray(rng.normal(size=(t, 2 * l, c)), dtype)
    got = windows_gemm(km, win, interpret=True)
    want = windows_gemm_ref(km, win)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# stencil_direct — tiled VPU shift-FMA kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,r", [("box", 1), ("box", 2), ("box", 3),
                                     ("star", 2)])
@pytest.mark.parametrize("dims", [(16, 16), (40, 130), (128, 256), (37, 91)])
def test_stencil_direct_2d_vs_ref(shape, r, dims, rng):
    from repro.kernels.stencil_direct.ops import stencil2d
    from repro.kernels.stencil_direct.ref import stencil2d_ref
    spec = make_stencil(shape, 2, r, seed=13)
    x = jnp.asarray(rng.normal(size=(dims[0] + 2 * r, dims[1] + 2 * r)),
                    jnp.float32)
    got = stencil2d(spec.weights, x, interpret=True)
    want = stencil2d_ref(spec.weights, x)
    assert got.shape == dims
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,r", [(100, 1), (1000, 2), (4096, 3)])
def test_stencil_direct_1d_vs_ref(n, r, rng):
    from repro.kernels.stencil_direct.ops import stencil1d
    spec = make_stencil("box", 1, r, seed=3)
    x = rng.normal(size=(n + 2 * r,)).astype(np.float32)
    got = stencil1d(spec.weights, jnp.asarray(x), interpret=True)
    want = np.correlate(x, spec.weights[::-1], mode="valid")[::-1][::-1]
    # np.correlate(x, w) flips nothing for symmetric check; compute directly:
    want = np.array([np.dot(spec.weights, x[i:i + 2 * r + 1])
                     for i in range(n)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_pallas_direct_backend_full_stencils(rng):
    """Whole-engine pallas_direct backend vs direct for 1/2/3-D."""
    for shape, ndim, r in [("box", 2, 1), ("star", 2, 2), ("box", 3, 1)]:
        spec = make_stencil(shape, ndim, r, seed=1)
        dims = {2: (24, 40), 3: (9, 12, 20)}[ndim]
        x = jnp.asarray(
            rng.normal(size=tuple(s + 2 * r for s in dims)), jnp.float32)
        want = apply_stencil(spec, x, backend="direct")
        got = apply_stencil(spec, x, backend="pallas_direct")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_pallas_backends_via_engine(rng):
    """pallas_mxu / pallas_sptc engine paths vs direct on a 2-D box."""
    spec = make_stencil("box", 2, 2, seed=9)
    x = jnp.asarray(rng.normal(size=(36, 52)), jnp.float32)
    want = apply_stencil(spec, x, backend="direct")
    for backend in ("pallas_mxu", "pallas_sptc"):
        got = apply_stencil(spec, x, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5, err_msg=backend)


# ---------------------------------------------------------------------------
# conv1d — depthwise causal conv (the technique's LM integration point)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,t,d,k", [(1, 16, 8, 4), (2, 100, 64, 4),
                                     (3, 257, 128, 4), (1, 32, 200, 2)])
def test_conv1d_vs_ref(b, t, d, k, dtype, rng):
    from repro.kernels.conv1d.ops import conv1d_causal
    from repro.kernels.conv1d.ref import conv1d_causal_ref
    x = jnp.asarray(rng.normal(size=(b, t, d)), dtype)
    w = jnp.asarray(rng.normal(size=(k, d)), dtype)
    got = conv1d_causal(x, w, interpret=True)
    want = conv1d_causal_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_conv1d_causality(rng):
    """Output at t must not depend on inputs after t."""
    from repro.kernels.conv1d.ref import conv1d_causal_ref
    x = jnp.asarray(rng.normal(size=(1, 20, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    y1 = conv1d_causal_ref(x, w)
    x2 = x.at[:, 10:, :].set(999.0)
    y2 = conv1d_causal_ref(x2, w)
    np.testing.assert_array_equal(np.asarray(y1[:, :10]),
                                  np.asarray(y2[:, :10]))
