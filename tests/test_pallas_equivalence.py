"""CI equivalence sweep: every Pallas backend vs the jnp oracles.

Runs entirely in interpret mode (the CI container has no TPU), over the
registry cross-product radius x dimensionality x shape the paper
benchmarks (§4.1), so a lowering regression in any Pallas backend —
including the fused SpTC v2 kernel behind ``pallas_sptc`` — fails tier-1
before it can reach hardware.  Grids are kept just above one L-tile to
stay inside the tier-1 time budget.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import apply_stencil
from repro.core.stencil import make_stencil
from repro.kernels.dispatch import PALLAS_BACKENDS

RADII = (1, 2, 3)
#: (shape, ndim) registry; 1-D star degenerates to the 1-D box pattern but
#: exercises the star-axis plan mode (and so the fused kernel's fast path).
POINTS = (("box", 1), ("star", 1), ("box", 2), ("star", 2))


def _grid(ndim, radius):
    n = 26 + 2 * radius            # a couple of rows past one L-tile
    return (n,) if ndim == 1 else (n, n + 6)


@pytest.mark.parametrize("radius", RADII)
@pytest.mark.parametrize("shape,ndim", POINTS)
def test_pallas_backends_match_direct(shape, ndim, radius, rng):
    spec = make_stencil(shape, ndim, radius, seed=10 * ndim + radius)
    x = jnp.asarray(rng.normal(size=_grid(ndim, radius)), jnp.float32)
    want = np.asarray(apply_stencil(spec, x, backend="direct"))
    for backend in PALLAS_BACKENDS:
        got = apply_stencil(spec, x, backend=backend)
        assert got.shape == want.shape, backend
        np.testing.assert_allclose(
            np.asarray(got), want, rtol=3e-5, atol=3e-5,
            err_msg=f"{backend} diverged on {shape}/{ndim}d r={radius}")
