"""Roofline analyzer tests: HLO parsing (scan trip counts, dot FLOPs,
collective bytes), term arithmetic, and 6ND counting."""
import jax
import jax.numpy as jnp

from repro.roofline import analysis as RA
from repro.roofline.hlo_parse import analyze, parse_module


HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot = f32[8,8]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%g0, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %big = f32[16,32]{1,0} broadcast(%a), dimensions={}
  %dot2 = f32[16,16]{1,0} dot(%big, %big), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %init = (s32[], f32[8,8]) tuple-select()
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[64,8]{1,0} all-gather(%a), dimensions={0}
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_module_structure():
    comps = parse_module(HLO)
    assert set(comps) >= {"body", "cond", "main"}
    assert any(i.opcode == "while" for i in comps["main"].instrs)


def test_analyze_scan_trip_multiplication():
    t = analyze(HLO)
    # dot inside while: 2*8*8*8 = 1024 flops x 5 trips = 5120
    # dot2 in entry: out (16,16), contract 32 -> 2*16*16*32 = 16384
    assert t.flops == 5 * 1024 + 16384
    # all-reduce f32[8,8] = 256B x 5; all-gather f32[64,8] = 2048B
    assert t.coll_bytes["all-reduce"] == 5 * 256
    assert t.coll_bytes["all-gather"] == 2048


def test_analyze_real_compiled_module():
    """End-to-end vs a known jitted scan on the real CPU backend."""
    def g(a, b):
        def body(x, _):
            return jnp.tanh(x @ b), None
        y, _ = jax.lax.scan(body, a, None, length=6)
        return y
    A = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(g).lower(A, A).compile()
    t = analyze(c.as_text())
    assert t.flops == 6 * 2 * 64 ** 3
    # raw cost_analysis counts the body once -> undercount confirmed
    ca = c.cost_analysis()
    if isinstance(ca, list):        # older jax returns [dict], newer dict
        ca = ca[0]
    assert ca["flops"] < t.flops


def test_roofline_terms_and_bottleneck():
    r = RA.Roofline(arch="a", cell="c", mesh="m", chips=256,
                    flops=256 * 197e12,          # exactly 1s compute
                    hbm_bytes=256 * 819e9 * 0.5,  # 0.5s memory
                    coll_bytes=256 * 50e9 * 0.25,  # 0.25s collective
                    coll_by_op={}, model_flops=128 * 197e12,
                    per_device_bytes=10 ** 9)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 0.25) < 1e-9
    assert r.bottleneck == "compute"
    assert abs(r.mfu - 0.5) < 1e-9          # half the compiled flops useful
    assert abs(r.useful_flops_frac - 0.5) < 1e-9


def test_model_flops_moe_discounts_inactive_experts():
    from repro.configs.base import SHAPE_BY_NAME
    from repro.configs.registry import get_config
    from repro.models import model as M
    cfg = get_config("granite-moe-3b-a800m", smoke=True)  # 8 experts top-2
    shapes = jax.eval_shape(
        lambda k: M.init_params(cfg, k)[0], jax.random.PRNGKey(0))
    total, active = RA.count_active_params(cfg, shapes)
    assert active < total
    cell = SHAPE_BY_NAME["train_4k"]
    mf = RA.model_flops_for_cell(cfg, cell, shapes)
    assert mf == 6.0 * active * cell.global_batch * cell.seq_len


def test_collective_bytes_legacy_parser():
    got = RA.collective_bytes(HLO)
    assert got["all-gather"] == 2048
