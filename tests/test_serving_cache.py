"""Ring-cache unit tests: slot arithmetic, packing, wrap-around masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.serving import cache as C
from repro.serving import engine as E
from repro.models import model as M


def test_ring_pack_short_sequence():
    k = jnp.arange(2 * 1 * 3, dtype=jnp.float32).reshape(1, 1, 6, 1, 1)[:, :, :3]
    k = jnp.arange(3, dtype=jnp.float32).reshape(1, 1, 3, 1, 1)
    out = C.ring_pack(k, ring=5)
    assert out.shape == (1, 1, 5, 1, 1)
    np.testing.assert_array_equal(np.asarray(out[0, 0, :, 0, 0]),
                                  [0, 1, 2, 0, 0])


def test_ring_pack_wraparound():
    # positions 0..6 into ring 4: keep last 4 (3,4,5,6) at slots p%4
    k = jnp.arange(7, dtype=jnp.float32).reshape(1, 1, 7, 1, 1)
    out = C.ring_pack(k, ring=4)
    # slot0=4, slot1=5, slot2=6, slot3=3
    np.testing.assert_array_equal(np.asarray(out[0, 0, :, 0, 0]),
                                  [4, 5, 6, 3])


def test_ring_positions():
    np.testing.assert_array_equal(np.asarray(C.ring_positions(3, 5)),
                                  [0, 1, 2, -1, -1])
    np.testing.assert_array_equal(np.asarray(C.ring_positions(7, 4)),
                                  [4, 5, 6, 3])


def test_write_token():
    kc = jnp.zeros((2, 4, 1, 1))
    k_new = jnp.ones((2, 1, 1, 1))
    out = C.write_token(kc, k_new, 2)
    np.testing.assert_array_equal(np.asarray(out[:, 2]), 1.0)
    assert float(out.sum()) == 2.0


def test_decode_past_ring_wraps_consistently():
    """Decode beyond the ring length on a SWA arch: positions stay right
    and old slots get overwritten (window semantics preserved)."""
    cfg = get_config("starcoder2-7b", smoke=True)   # window 16
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.ones((1, 20), jnp.int32)            # > window
    logits, cc = E.prefill(params, cfg, prompt, cache_len=64)
    assert cc["k"].shape[2] == 16                    # ring = window
    for i in range(5):
        lg, cc = E.decode_step(params, cfg, cc,
                               jnp.full((1, 1), 3, jnp.int32))
        assert np.all(np.isfinite(np.asarray(lg)))
    assert int(cc["pos"]) == 25
    # every slot now holds a recent position in (pos-16, pos]
    kvp = np.asarray(cc["kv_pos"])
    assert kvp.min() > 25 - 17 and kvp.max() == 24


def test_cache_dtypes_follow_config():
    cfg = get_config("qwen3-1.7b")                   # bf16 full config
    cc = jax.eval_shape(lambda: C.init_cache(cfg, 2, 128))
    assert cc["k"].dtype == jnp.bfloat16
    assert cc["kv_pos"].dtype == jnp.int32


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "whisper-large-v3",
                                  "llama-3.2-vision-11b"])
def test_structured_cache_shapes(arch):
    cfg = get_config(arch, smoke=True)
    cc = C.init_cache(cfg, batch=2, cache_len=32)
    if cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        assert cc["shared"]["k"].shape[0] == ng
        assert cc["ssm"].shape[0] == cfg.n_layers
    if cfg.family == "encdec":
        assert cc["cross"]["k"].shape[2] == cfg.n_frames
    if cfg.family == "vlm":
        assert cc["cross"]["k"].shape[2] == cfg.n_img_tokens
