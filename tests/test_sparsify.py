"""Strided-swap 2:4 sparsification + encoding (paper §3.2.2) tests.

The heart of the paper: the column permutation must turn the banded kernel
matrix into a valid 2:4 pattern for EVERY radius, and the compressed
(values, metadata) encoding must round-trip exactly.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sparsify import (apply_col_perm, decode_24,
                                 encode_24, is_24_sparse,
                                 sparsify_stencil_kernel, strided_swap_perm)
from repro.core.transform import default_l, kernel_matrix


# ---------------------------------------------------------------------------
# the strided swap permutation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L", [4, 6, 8, 10, 16, 32])
def test_perm_is_involution(L):
    perm = strided_swap_perm(L)
    np.testing.assert_array_equal(perm[perm], np.arange(2 * L))


@pytest.mark.parametrize("L", [4, 8, 16])
def test_perm_swaps_odd_fixes_even(L):
    perm = strided_swap_perm(L)
    for p in range(L):
        if p % 2 == 1:
            assert perm[p] == p + L and perm[p + L] == p
        else:
            assert perm[p] == p
    # upper-half odd positions (p >= L, p odd offset) hold lower-half odds
    for p in range(L, 2 * L):
        if (p - L) % 2 == 1:
            assert perm[p] == p - L


@pytest.mark.parametrize("r", list(range(1, 12)))
def test_strided_swap_yields_24_for_all_radii(r):
    """Paper §3.2.2 step 2 — the structural guarantee, swept over radius."""
    w = np.random.default_rng(r).normal(size=2 * r + 1)
    w[w == 0] = 1.0
    L = default_l(r)
    K = kernel_matrix(w, L=L, pad_width=True)
    assert not is_24_sparse(K) or r == 0   # band is clustered pre-swap
    Kp = apply_col_perm(K, strided_swap_perm(L))
    assert is_24_sparse(Kp)
    # exactly 2r+1 non-zeros per row survive the permutation
    assert np.all((Kp != 0).sum(axis=1) == 2 * r + 1)


@given(r=st.integers(1, 8), seed=st.integers(0, 1000))
@settings(max_examples=60, deadline=None)
def test_swap_preserves_multiset_and_equivalence(r, seed):
    """Permutation correctness: K' @ x' == K @ x when x is row-permuted by
    the same involution (paper §3.3 zero-cost row swap)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=2 * r + 1)
    L = default_l(r)
    K = kernel_matrix(w, L=L, pad_width=True)
    perm = strided_swap_perm(L)
    Kp = apply_col_perm(K, perm)
    x = rng.normal(size=(2 * L, 7))
    np.testing.assert_allclose(Kp @ x[perm], K @ x, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# 2:4 encoding (paper §3.2.2 step 3, Figure 5)
# ---------------------------------------------------------------------------

def _random_24(rng, m, k, density=0.5):
    """Random matrix that satisfies 2:4 by construction."""
    out = np.zeros((m, k))
    for i in range(m):
        for s in range(k // 4):
            nnz = rng.integers(0, 3)            # 0, 1 or 2 per segment
            pos = rng.choice(4, size=nnz, replace=False)
            out[i, 4 * s + pos] = rng.normal(size=nnz)
    return out


@given(m=st.integers(1, 8), segs=st.integers(1, 8), seed=st.integers(0, 999))
@settings(max_examples=60, deadline=None)
def test_encode_decode_roundtrip(m, segs, seed):
    rng = np.random.default_rng(seed)
    mat = _random_24(rng, m, 4 * segs)
    sp = encode_24(mat)
    np.testing.assert_array_equal(decode_24(sp), mat)
    # metadata strictly increasing within each segment pair
    meta = sp.meta.reshape(m, segs, 2)
    assert np.all(meta[..., 0] < meta[..., 1])


def test_encode_rejects_non_24():
    bad = np.zeros((1, 4))
    bad[0, :3] = 1.0                            # 3 non-zeros in a segment
    with pytest.raises(ValueError):
        encode_24(bad)
    with pytest.raises(ValueError):
        encode_24(np.ones((2, 6)))              # width not multiple of 4


def test_encode_placeholder_rules():
    """Figure 5's zero-placeholder rule: segments with <2 nnz keep consistent
    dims and strictly-increasing metadata."""
    mat = np.zeros((3, 4))
    mat[0, 1] = 5.0                             # one nnz at p=1
    mat[1, 3] = 7.0                             # one nnz at p=3
    sp = encode_24(mat)                         # row 2 empty
    np.testing.assert_array_equal(sp.meta[0], [1, 3])
    np.testing.assert_array_equal(sp.values[0], [5.0, 0.0])
    np.testing.assert_array_equal(sp.meta[1], [2, 3])
    np.testing.assert_array_equal(sp.values[1], [0.0, 7.0])
    np.testing.assert_array_equal(sp.meta[2], [2, 3])
    np.testing.assert_array_equal(sp.values[2], [0.0, 0.0])
    np.testing.assert_array_equal(decode_24(sp), mat)


def test_meta_bits_lsb_first():
    """Hardware packing: 2-bit fields, LSB-first (paper Fig. 5)."""
    mat = np.zeros((1, 8))
    mat[0, [0, 2]] = [1.0, 2.0]                 # seg 0 -> indices (0, 2)
    mat[0, [5, 7]] = [3.0, 4.0]                 # seg 1 -> indices (1, 3)
    sp = encode_24(mat)
    words = sp.meta_bits()
    assert words.shape == (1, 1)
    # fields in order: 0,2,1,3 -> bits 00 | 10<<2 | 01<<4 | 11<<6
    assert words[0, 0] == (0 | (2 << 2) | (1 << 4) | (3 << 6))


def test_gather_indices():
    mat = np.zeros((1, 8))
    mat[0, [1, 2, 4, 6]] = [1, 2, 3, 4]
    sp = encode_24(mat)
    np.testing.assert_array_equal(sp.gather_indices()[0], [1, 2, 4, 6])


# ---------------------------------------------------------------------------
# end-to-end sparsified stencil kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r", [1, 2, 3, 5, 7])
def test_sparsify_stencil_kernel(r):
    w = np.random.default_rng(r).normal(size=2 * r + 1)
    sk = sparsify_stencil_kernel(w)
    L = default_l(r)
    assert sk.L == L and sk.window == 2 * L
    assert sk.values.shape == (L, L)            # K/2 = 2L/2 = L
    # decompressed(perm applied) equals the original banded matrix
    K = kernel_matrix(w, L=L, pad_width=True)
    dense_perm = decode_24(sk.sparse)
    np.testing.assert_allclose(
        apply_col_perm(dense_perm, np.argsort(sk.perm)), K, rtol=1e-12)


@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_encode_decode_roundtrip_banded_radii(r):
    """Deterministic encode/decode round-trip over the actual stencil bands
    (radii 1-4), exact to the bit — no hypothesis required."""
    w = np.random.default_rng(100 + r).normal(size=2 * r + 1)
    w[w == 0] = 0.5
    L = default_l(r)
    Kp = apply_col_perm(kernel_matrix(w, L=L, pad_width=True),
                        strided_swap_perm(L))
    sp = encode_24(Kp)
    np.testing.assert_array_equal(decode_24(sp), Kp)
    # the full pipeline's compressed operand decodes to the same matrix
    sk = sparsify_stencil_kernel(w, L=L)
    np.testing.assert_array_equal(decode_24(sk.sparse), Kp)
    meta = sp.meta.reshape(sp.m, sp.k // 4, 2)
    assert np.all(meta[..., 0] < meta[..., 1])
    assert np.all((meta >= 0) & (meta < 4))


def _meta_bits_ref(meta: np.ndarray) -> np.ndarray:
    """Independent scalar-loop recomputation of Sparse24.meta_bits()."""
    m, half = meta.shape
    nwords = -(-half // 16)
    words = np.zeros((m, nwords), dtype=np.uint32)
    for i in range(m):
        for j in range(half):
            words[i, j // 16] |= np.uint32(int(meta[i, j]) & 0x3) << (2 * (j % 16))
    return words


@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_meta_bits_matches_scalar_reference(r):
    """Bit packing of real stencil metadata == LSB-first scalar reference."""
    sk = sparsify_stencil_kernel(np.random.default_rng(r).normal(size=2 * r + 1))
    np.testing.assert_array_equal(sk.sparse.meta_bits(),
                                  _meta_bits_ref(sk.sparse.meta))


def test_meta_bits_multiword_rows():
    """Rows wider than 16 segments span multiple uint32 words (k/2 > 16)."""
    rng = np.random.default_rng(3)
    k = 80                                      # 20 segments -> half = 40 -> 3 words
    mat = np.zeros((4, k))
    for i in range(4):
        for s in range(k // 4):
            pos = rng.choice(4, size=2, replace=False)
            mat[i, 4 * s + np.sort(pos)] = rng.normal(size=2)
    sp = encode_24(mat)
    words = sp.meta_bits()
    assert words.shape == (4, 3) and words.dtype == np.uint32
    np.testing.assert_array_equal(words, _meta_bits_ref(sp.meta))
    # every 2-bit field decodes back to the stored metadata (padding = 0)
    unpacked = np.zeros_like(sp.meta)
    for j in range(sp.meta.shape[1]):
        unpacked[:, j] = (words[:, j // 16] >> (2 * (j % 16))) & 0x3
    np.testing.assert_array_equal(unpacked, sp.meta)


def test_sparsity_ratio_maximizes_sptc_utilization():
    """Paper §3.2.2 step 1: L = 2r+2 gives density 50% exactly at the padded
    2:4 budget — every compressed slot except one per row is useful."""
    for r in range(1, 8):
        sk = sparsify_stencil_kernel(np.ones(2 * r + 1))
        useful = (sk.values != 0).sum(axis=1)
        assert np.all(useful == 2 * r + 1)      # of L = 2r+2 slots
