"""serving driver: plan-key bucketing, padding round-trip, backpressure,
metrics, scheduler semantics, and the shared LM decode path.

Acceptance (ISSUE 6): for a randomized mix of >=100 jobs across >=3
specs/shapes, batched-driver outputs must match per-job ``tuned_apply``
(and the ``direct`` oracle), with measured batch occupancy > 1.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import apply_stencil
from repro.core.stencil import make_stencil
from repro.serving import (BatchPolicy, BatchScheduler, QueueFullError,
                           StencilDriver)
from repro.serving.metrics import LatencyWindow
from repro.tuner import PlanCache, batch_group_key, tuned_apply

MODE = "cost"          # static cost model: no timing loops in unit tests


def _grid(spec, dims, rng, dtype=jnp.float32):
    shape = tuple(s + 2 * spec.radius for s in dims)
    return jnp.asarray(rng.normal(size=shape), dtype)


def _mixed_jobs(n, rng, lo=12, hi=28):
    specs = [make_stencil("star", 2, 1, seed=1),
             make_stencil("box", 2, 2, seed=2),
             make_stencil("box", 1, 1, seed=3)]
    jobs = []
    for i in range(n):
        spec = specs[i % len(specs)]
        if spec.ndim == 2:
            dims = (int(rng.integers(lo, hi)), int(rng.integers(lo, hi)))
        else:
            dims = (int(rng.integers(4 * lo, 4 * hi)),)
        jobs.append((spec, _grid(spec, dims, rng)))
    return jobs


# ---------------------------------------------------------------------------
# plan-key bucketing
# ---------------------------------------------------------------------------

def test_group_key_is_tuner_plan_key(rng):
    spec = make_stencil("star", 2, 1, seed=0)
    drv = StencilDriver(cache=PlanCache(), mode=MODE, autostart=False)
    a = _grid(spec, (20, 24), rng)           # both bucket to (32, 32) + halo
    b = _grid(spec, (28, 30), rng)
    assert drv.group_key(spec, a) == drv.group_key(spec, b)
    assert drv.group_key(spec, a) == batch_group_key(spec, a.shape, a.dtype)
    # dtype and spec content split the group
    c = _grid(spec, (20, 24), rng, jnp.bfloat16)
    assert drv.group_key(spec, c) != drv.group_key(spec, a)
    other = make_stencil("star", 2, 1, seed=9)
    assert drv.group_key(other, a) != drv.group_key(spec, a)
    drv.close()


def test_exact_padding_splits_groups_by_shape(rng):
    spec = make_stencil("box", 1, 1, seed=4)
    drv = StencilDriver(cache=PlanCache(), mode=MODE, padding="exact",
                        autostart=False)
    a, b = _grid(spec, (50,), rng), _grid(spec, (51,), rng)
    assert drv.group_key(spec, a) != drv.group_key(spec, b)
    assert drv.group_key(spec, a) == drv.group_key(spec, a)
    drv.close()


def test_submit_validates_ndim_and_halo(rng):
    spec = make_stencil("star", 2, 1, seed=0)
    with StencilDriver(cache=PlanCache(), mode=MODE) as drv:
        with pytest.raises(ValueError, match="2-D"):
            drv.submit(spec, jnp.zeros((8,)))
        with pytest.raises(ValueError, match="halo"):
            drv.submit(spec, jnp.zeros((2, 8)))
        # a k-step job needs the k·r halo, and k must be positive
        with pytest.raises(ValueError, match="2kr=4"):
            drv.submit(spec, jnp.zeros((4, 8)), temporal_steps=2)
        with pytest.raises(ValueError, match="temporal_steps"):
            drv.submit(spec, jnp.zeros((8, 8)), temporal_steps=0)


def test_temporal_jobs_bucket_and_run_separately(rng):
    """temporal_steps extends the plan key: a k-step job never co-batches
    with single-step jobs, and its result advances k steps."""
    spec = make_stencil("star", 2, 1, seed=0)
    cache = PlanCache()
    x1 = _grid(spec, (20, 24), rng)                    # r halo
    xk = jnp.asarray(rng.normal(size=(24, 28)), jnp.float32)   # 2·r halo
    with StencilDriver(cache=cache, mode=MODE,
                       policy=BatchPolicy(max_batch=4,
                                          max_wait_ms=1.0)) as drv:
        assert drv.group_key(spec, xk, temporal_steps=2) != \
            drv.group_key(spec, xk)
        f1 = drv.submit(spec, x1)
        fk = drv.submit(spec, xk, temporal_steps=2)
        y1, yk = f1.result(timeout=120), fk.result(timeout=120)
    np.testing.assert_allclose(
        np.asarray(y1),
        np.asarray(tuned_apply(spec, x1, cache=cache, mode=MODE)),
        rtol=2e-5, atol=2e-5)
    want = apply_stencil(spec, apply_stencil(spec, xk, backend="direct"),
                         backend="direct")
    assert yk.shape == tuple(s - 4 * spec.radius for s in xk.shape)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# padding policy round-trip vs per-job oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("padding", ["bucket", "max", "exact"])
def test_padding_roundtrip_matches_per_job_oracle(padding, rng):
    cache = PlanCache()
    jobs = _mixed_jobs(18, rng)
    with StencilDriver(cache=cache, mode=MODE, padding=padding,
                       policy=BatchPolicy(max_batch=6, max_wait_ms=1.0)) as drv:
        got = drv.map(jobs, timeout=120)
    for (spec, x), y in zip(jobs, got):
        want = tuned_apply(spec, x, cache=cache, mode=MODE)
        assert y.shape == tuple(s - 2 * spec.radius for s in x.shape)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_acceptance_100_jobs_occupancy_and_correctness(rng):
    """ISSUE 6 acceptance: >=100 jobs, >=3 specs, occupancy > 1, outputs
    match per-job tuned_apply AND the direct oracle."""
    cache = PlanCache()
    jobs = _mixed_jobs(102, rng)
    drv = StencilDriver(cache=cache, mode=MODE,
                        policy=BatchPolicy(max_batch=16, max_wait_ms=2.0),
                        autostart=False)
    futures = [drv.submit(spec, x) for spec, x in jobs]
    drv.start()
    got = [f.result(timeout=300) for f in futures]
    metrics = drv.metrics()
    drv.close()

    for (spec, x), y in zip(jobs, got):
        tuned = tuned_apply(spec, x, cache=cache, mode=MODE)
        direct = apply_stencil(spec, x, backend="direct")
        # padding to the bucket shape changes the compiled program, so
        # ulp-level reassociation vs the exact-shape run is possible —
        # tolerance stays at float32-epsilon scale, not loose
        np.testing.assert_allclose(np.asarray(y), np.asarray(tuned),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(y), np.asarray(direct),
                                   rtol=2e-5, atol=2e-5)
    overall = metrics["overall"]
    assert overall["completed"] == len(jobs)
    assert overall["batch_occupancy"] > 1.0
    assert overall["batches"] < len(jobs)
    assert metrics["tuner"]["plan_hit_rate"] > 0


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_backpressure_reject_and_metrics(rng):
    spec = make_stencil("box", 1, 1, seed=5)
    drv = StencilDriver(cache=PlanCache(), mode=MODE,
                        policy=BatchPolicy(max_batch=8, max_queue=3,
                                           overflow="reject"),
                        autostart=False)
    xs = [_grid(spec, (40,), rng) for _ in range(4)]
    futures = [drv.submit(spec, x) for x in xs[:3]]
    with pytest.raises(QueueFullError):
        drv.submit(spec, xs[3])
    key = drv.group_key(spec, xs[0])
    assert drv.queue_depth() == 3 and drv.queue_depth(key) == 3
    m = drv.metrics()["plans"][key]
    assert m["rejected"] == 1 and m["submitted"] == 3
    drv.start()
    for f in futures:
        f.result(timeout=60)
    drv.close()


def test_backpressure_block_completes(rng):
    spec = make_stencil("box", 1, 1, seed=5)
    with StencilDriver(cache=PlanCache(), mode=MODE,
                       policy=BatchPolicy(max_batch=4, max_wait_ms=0.0,
                                          max_queue=2,
                                          overflow="block")) as drv:
        xs = [_grid(spec, (40,), rng) for _ in range(10)]
        got = drv.map([(spec, x) for x in xs], timeout=120)
    assert len(got) == 10
    want = apply_stencil(spec, xs[0], backend="direct")
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_counters_and_latency(rng):
    spec = make_stencil("star", 2, 1, seed=0)
    cache = PlanCache()
    drv = StencilDriver(cache=cache, mode=MODE,
                        policy=BatchPolicy(max_batch=4, max_wait_ms=1.0),
                        autostart=False)
    xs = [_grid(spec, (16, 18), rng) for _ in range(6)]
    futures = [drv.submit(spec, x) for x in xs]
    drv.start()
    [f.result(timeout=120) for f in futures]
    metrics = drv.metrics()
    drv.close()

    key = drv.group_key(spec, xs[0])
    m = metrics["plans"][key]
    assert m["submitted"] == 6 and m["completed"] == 6 and m["failed"] == 0
    assert m["batches"] == 2 and m["batch_occupancy"] == 3.0
    assert 0 < m["padding_efficiency"] <= 1.0
    assert m["latency"]["count"] == 6
    assert m["latency"]["p99_ms"] >= m["latency"]["p50_ms"] > 0
    assert m["queue_depth"] == 0
    # tuner stats ride along: one tune, then plan hits on later batches
    assert metrics["tuner"]["tunes"] == 1
    assert metrics["tuner"]["plan_hits"] >= 1


def test_latency_window_percentiles():
    w = LatencyWindow(maxlen=16)
    for ms in [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]:
        w.observe(ms / 1e3)
    assert w.percentile(50) == pytest.approx(5e-3)
    assert w.percentile(99) == pytest.approx(10e-3)
    assert w.as_dict()["count"] == 10
    assert LatencyWindow().as_dict()["p99_ms"] == 0.0


def test_group_metrics_concurrent_bumps_lose_no_increments():
    # submit-path counters are bumped from caller threads while the batch
    # thread bumps completion counters; a bare `+= 1` interleaves its
    # LOAD/ADD/STORE under the GIL and drops increments.  bump() must not.
    from repro.serving.metrics import GroupMetrics

    m = GroupMetrics()
    n_threads, n_iters = 8, 2000

    def hammer():
        for _ in range(n_iters):
            m.bump(submitted=1, batched_jobs=2)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.submitted == n_threads * n_iters
    assert m.batched_jobs == 2 * n_threads * n_iters
    assert m.as_dict()["submitted"] == n_threads * n_iters


def test_latency_window_concurrent_observe_and_percentile():
    # percentile() sorts the window while observe() appends from the
    # batch thread; without the internal snapshot this raises
    # "deque mutated during iteration".
    w = LatencyWindow(maxlen=256)
    stop = threading.Event()
    errors = []

    def writer():
        i = 0
        while not stop.is_set():
            w.observe(i * 1e-4)
            i += 1

    def reader():
        try:
            for _ in range(500):
                w.percentile(99)
                w.as_dict()
        except RuntimeError as e:          # pragma: no cover — the bug
            errors.append(e)

    wt = threading.Thread(target=writer)
    rt = threading.Thread(target=reader)
    wt.start()
    rt.start()
    rt.join()
    stop.set()
    wt.join()
    assert not errors


# ---------------------------------------------------------------------------
# scheduler semantics (traffic-class agnostic layer)
# ---------------------------------------------------------------------------

def test_scheduler_packs_up_to_max_batch():
    seen = []
    sched = BatchScheduler(lambda key, ps: seen.append(list(ps)) or ps,
                           BatchPolicy(max_batch=4, max_wait_ms=50.0),
                           autostart=False)
    futures = [sched.submit("k", i) for i in range(10)]
    sched.start()
    assert [f.result(timeout=30) for f in futures] == list(range(10))
    sched.shutdown()
    assert sorted(len(b) for b in seen) == [2, 4, 4]


def test_scheduler_groups_by_key_and_preserves_order():
    batches = {}
    def run(key, ps):
        batches.setdefault(key, []).extend(ps)
        return ps
    sched = BatchScheduler(run, BatchPolicy(max_batch=8, max_wait_ms=50.0),
                           autostart=False)
    futures = [sched.submit(i % 2, i) for i in range(8)]
    sched.start()
    [f.result(timeout=30) for f in futures]
    sched.shutdown()
    assert batches[0] == [0, 2, 4, 6] and batches[1] == [1, 3, 5, 7]


def test_scheduler_executor_error_propagates_to_futures():
    def boom(key, ps):
        raise RuntimeError("executor exploded")
    sched = BatchScheduler(boom, BatchPolicy(max_batch=2, max_wait_ms=0.0))
    f = sched.submit("k", 1)
    with pytest.raises(RuntimeError, match="executor exploded"):
        f.result(timeout=30)
    # one bad batch must not wedge the worker
    ok = BatchScheduler(lambda k, ps: ps, BatchPolicy(max_wait_ms=0.0))
    assert ok.submit("k", 7).result(timeout=30) == 7
    ok.shutdown()
    sched.shutdown()


def test_scheduler_result_count_mismatch_is_an_error():
    sched = BatchScheduler(lambda k, ps: ps[:-1],
                           BatchPolicy(max_wait_ms=0.0))
    f = sched.submit("k", 1)
    with pytest.raises(RuntimeError, match="results"):
        f.result(timeout=30)
    sched.shutdown()


def test_scheduler_shutdown_drains_then_rejects():
    sched = BatchScheduler(lambda k, ps: ps,
                           BatchPolicy(max_batch=64, max_wait_ms=10_000.0),
                           autostart=False)
    futures = [sched.submit("k", i) for i in range(3)]
    sched.start()
    sched.shutdown(wait=True)        # drains despite the huge max_wait
    assert [f.result(timeout=1) for f in futures] == [0, 1, 2]
    with pytest.raises(RuntimeError):
        sched.submit("k", 99)


def test_scheduler_drain_blocks_until_empty():
    done = []
    def slowish(key, ps):
        time.sleep(0.05)
        done.extend(ps)
        return ps
    sched = BatchScheduler(slowish,
                           BatchPolicy(max_batch=2, max_wait_ms=10_000.0))
    for i in range(4):
        sched.submit("k", i)
    sched.drain()
    assert sorted(done) == [0, 1, 2, 3] and sched.queue_depth() == 0
    sched.shutdown()


def test_scheduler_blocking_submit_unblocks_from_worker():
    release = threading.Event()
    def gated(key, ps):
        release.wait(5)
        return ps
    sched = BatchScheduler(gated, BatchPolicy(max_batch=1, max_wait_ms=0.0,
                                              max_queue=1, overflow="block"))
    f0 = sched.submit("k", 0)
    results = []
    t = threading.Thread(
        target=lambda: results.append(sched.submit("k", 1).result(10)))
    t.start()
    time.sleep(0.05)
    release.set()
    t.join(10)
    assert not t.is_alive() and f0.result(5) == 0 and results == [1]
    sched.shutdown()


def test_batch_policy_validation():
    with pytest.raises(ValueError, match="max_batch"):
        BatchPolicy(max_batch=0)
    with pytest.raises(ValueError, match="overflow"):
        BatchPolicy(overflow="drop")
    with pytest.raises(ValueError, match="max_queue"):
        BatchPolicy(max_queue=0)


# ---------------------------------------------------------------------------
# LM decode traffic on the same scheduling layer
# ---------------------------------------------------------------------------

def test_generate_driver_shares_scheduler_semantics():
    jax = pytest.importorskip("jax")
    from repro.configs.registry import get_config
    from repro.models import model as M
    from repro.serving import GenerateDriver
    from repro.serving import engine as E

    cfg = get_config("qwen3-1.7b", smoke=True)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)

    drv = GenerateDriver(params, cfg, cache_len=16, autostart=False)
    futures = [drv.submit(prompts[i], 4) for i in range(2)]
    drv.start()
    got = [f.result(timeout=300) for f in futures]
    metrics = drv.metrics()
    drv.close()

    # both aligned requests packed into ONE position-aligned batch
    assert metrics["overall"]["batches"] == 1
    assert metrics["overall"]["batch_occupancy"] == 2.0
    want, _ = E.generate(params, cfg, prompts, n_new=4, cache_len=16)
    np.testing.assert_array_equal(np.asarray(jnp.stack(got)),
                                  np.asarray(want))
    # misaligned prompt lengths land in different groups
    drv2 = GenerateDriver(params, cfg, cache_len=16, autostart=False)
    k1 = drv2.group_key(prompts[0], 4)
    k2 = drv2.group_key(prompts[0][:5], 4)
    assert k1 != k2
    with pytest.raises(ValueError, match="1-D"):
        drv2.submit(prompts, 4)
    drv2.close()
