"""Training substrate tests: optimizer, data determinism, checkpoint
atomicity + elastic restore, full train_step convergence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.training import (TrainConfig, checkpoint as ckpt, data,
                            init_state, make_train_step, optimizer as O)


def test_schedule_warmup_and_decay():
    oc = O.OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                     min_lr_frac=0.1)
    assert float(O.schedule(oc, jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(O.schedule(oc, jnp.asarray(10))), 1.0)
    np.testing.assert_allclose(float(O.schedule(oc, jnp.asarray(110))), 0.1,
                               rtol=1e-5)
    mid = float(O.schedule(oc, jnp.asarray(60)))
    assert 0.1 < mid < 1.0


def test_adamw_converges_quadratic():
    """AdamW drives a simple quadratic to its minimum."""
    oc = O.OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                     weight_decay=0.0, clip_norm=1e9)
    target = {"w": jnp.asarray([3.0, -2.0, 0.5])}
    params = {"w": jnp.zeros(3)}
    st = O.init(params)
    for _ in range(200):
        g = jax.tree.map(lambda p, t: p - t, params, target)
        params, st, m = O.apply(oc, st, g, jnp.float32)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target["w"]), atol=1e-2)
    assert float(m["grad_norm"]) < 0.1


def test_grad_clip():
    oc = O.OptConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    st = O.init(params)
    big = {"w": jnp.full(4, 1e6)}
    _, _, m = O.apply(oc, st, big, jnp.float32)
    assert float(m["grad_norm"]) > 1e5         # reported pre-clip


def test_data_deterministic_and_shifted():
    dc = data.DataConfig(vocab=128, seq_len=32, global_batch=4, seed=7)
    b1 = data.global_batch(dc, step=3)
    b2 = data.global_batch(dc, step=3)
    np.testing.assert_array_equal(b1, b2)       # pure fn of (seed, step)
    b3 = data.global_batch(dc, step=4)
    assert not np.array_equal(b1, b3)
    assert b1.shape == (4, 33) and b1.dtype == np.int32
    assert b1.min() >= 0 and b1.max() < 128


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, tree, extra={"step": 10})
    ckpt.save(d, 20, tree, extra={"step": 20})
    assert ckpt.latest_step(d) == 20
    # a stale .tmp dir (simulated crash) is ignored
    os.makedirs(os.path.join(d, "step_00000030.tmp"))
    assert ckpt.latest_step(d) == 20
    got, extra = ckpt.restore(d, tree)
    assert extra["step"] == 20
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {"x": jnp.zeros(1)}, keep=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_00000005"


def test_checkpoint_elastic_restore_resharded(tmp_path):
    """Save under one sharding, restore under another mesh layout."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(d, 1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = ckpt.restore(d, tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(d)
    ac.save(5, {"x": jnp.full(3, 7.0)}, extra={"step": 5})
    ac.wait()
    got, extra = ckpt.restore(d, {"x": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(got["x"]), 7.0)


@pytest.mark.parametrize("micro", [1, 2])
def test_train_step_decreases_loss(micro):
    cfg = get_config("qwen3-1.7b", smoke=True)
    tc = TrainConfig(microbatches=micro,
                     opt=O.OptConfig(lr=1e-2, warmup_steps=0,
                                     total_steps=50))
    state, _ = init_state(cfg, jax.random.PRNGKey(0))
    dc = data.DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=1)
    step = jax.jit(make_train_step(cfg, tc))
    losses = []
    for s in range(12):
        tok = jnp.asarray(data.global_batch(dc, 0))   # same batch: memorize
        state, m = step(state, tok)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8
    assert all(np.isfinite(losses))


def test_microbatch_equals_full_batch_grads():
    """Grad accumulation == single big batch (linearity check)."""
    from repro.training.train_step import loss_and_grads
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    state, _ = init_state(cfg, jax.random.PRNGKey(2))
    dc = data.DataConfig(vocab=cfg.vocab, seq_len=12, global_batch=4, seed=3)
    tok = jnp.asarray(data.global_batch(dc, 0))
    l1, _, g1 = loss_and_grads(cfg, TrainConfig(microbatches=1),
                               state.params, tok)
    l2, _, g2 = loss_and_grads(cfg, TrainConfig(microbatches=2),
                               state.params, tok)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_grad_compress_path_runs():
    cfg = get_config("qwen3-1.7b", smoke=True)
    tc = TrainConfig(grad_compress=True)
    state, _ = init_state(cfg, jax.random.PRNGKey(0))
    tok = jnp.ones((2, 9), jnp.int32)
    state2, m = make_train_step(cfg, tc)(state, tok)
    assert np.isfinite(float(m["loss"]))


def test_watchdog_flags_stragglers():
    from repro.training.fault_tolerance import Watchdog
    wd = Watchdog(straggler_factor=2.0)
    for _ in range(10):
        assert not wd.record(1.0)
    assert wd.record(5.0)
    assert not wd.record(1.1)
