"""Stencil -> kernel-matrix transform (paper §3.2.1) unit + property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.stencil import make_stencil, star_mask, StencilSpec
from repro.core.transform import (axis_decompose_star, band_density,
                                  decompose_rows, default_l, kernel_matrix)


def test_default_l_is_even_and_min():
    for r in range(1, 8):
        L = default_l(r)
        assert L == 2 * r + 2
        assert L % 2 == 0
        # paper §3.2.2 step 1: (2r+1)/(2r+L) = 50% - eps, i.e. L >= 2r+1
        assert band_density(r, L) <= 0.5


@pytest.mark.parametrize("r", [1, 2, 3, 5, 7])
def test_kernel_matrix_band_structure(r):
    w = np.arange(1, 2 * r + 2, dtype=np.float64)
    L = default_l(r)
    K = kernel_matrix(w, L=L, pad_width=False)
    assert K.shape == (L, 2 * r + L)
    for i in range(L):
        np.testing.assert_array_equal(K[i, i:i + 2 * r + 1], w)
        assert np.all(K[i, :i] == 0)
        assert np.all(K[i, i + 2 * r + 1:] == 0)


@pytest.mark.parametrize("r", [1, 2, 3])
def test_kernel_matrix_padded_width(r):
    w = np.ones(2 * r + 1)
    L = default_l(r)
    K = kernel_matrix(w, L=L, pad_width=True)
    assert K.shape == (L, 2 * L)
    # columns beyond 2r+L are structurally zero
    assert np.all(K[:, 2 * r + L:] == 0)


def test_kernel_matrix_rejects_bad_l():
    w = np.ones(5)  # r = 2
    with pytest.raises(ValueError):
        kernel_matrix(w, L=5)       # odd
    with pytest.raises(ValueError):
        kernel_matrix(w, L=4)       # < 2r+2
    with pytest.raises(ValueError):
        kernel_matrix(np.ones(4))   # even tap count


@given(r=st.integers(1, 6), c=st.integers(0, 200))
@settings(max_examples=40, deadline=None)
def test_kernel_matrix_matmul_is_stencil(r, c):
    """Y = K @ X computes L consecutive 1-D stencil outputs (paper Fig. 3)."""
    rng = np.random.default_rng(c)
    w = rng.normal(size=2 * r + 1)
    L = default_l(r)
    K = kernel_matrix(w, L=L, pad_width=False)
    x = rng.normal(size=(2 * r + L,))
    y = K @ x
    expect = np.array([np.dot(w, x[i:i + 2 * r + 1]) for i in range(L)])
    np.testing.assert_allclose(y, expect, rtol=1e-12)


@pytest.mark.parametrize("shape,ndim,r", [("box", 2, 1), ("box", 2, 3),
                                          ("star", 2, 2), ("box", 3, 1),
                                          ("star", 3, 2)])
def test_decompose_rows_reassembles(shape, ndim, r):
    spec = make_stencil(shape, ndim, r, seed=3)
    rows = decompose_rows(spec)
    rebuilt = np.zeros_like(spec.weights)
    for lead, wrow in rows:
        rebuilt[lead] = wrow
    np.testing.assert_array_equal(rebuilt, spec.weights)
    if shape == "star":
        # star: only the axis rows survive -> 2r off-center rows + 1 center
        assert len(rows) == (2 * r + 1 if ndim == 2 else 4 * r + 1)


@pytest.mark.parametrize("ndim,r", [(2, 1), (2, 3), (3, 2)])
def test_axis_decompose_star_counts_center_once(ndim, r):
    spec = make_stencil("star", ndim, r, seed=5)
    kernels = axis_decompose_star(spec)
    assert len(kernels) == ndim
    total = sum(k.sum() for k in kernels)
    np.testing.assert_allclose(total, spec.weights.sum(), rtol=1e-12)
    # center tap kept only in last-axis kernel
    for axis in range(ndim - 1):
        assert kernels[axis][r] == 0.0


def test_star_mask_and_spec_validation():
    m = star_mask(2, 2)
    assert m.sum() == 2 * 2 * 2 + 1
    w = np.ones((5, 5))
    with pytest.raises(ValueError):
        StencilSpec(shape="star", ndim=2, radius=2, weights=w)  # box weights
    with pytest.raises(ValueError):
        StencilSpec(shape="box", ndim=4, radius=1, weights=np.ones((3,) * 4))
