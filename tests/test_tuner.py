"""repro.tuner: plan keying, cache-hit/no-rejit, persistence, correctness.

Acceptance (ISSUE 1): repeated tuned_apply on the same (spec, shape,
dtype) must hit the plan cache with zero re-trace/re-jit; persistence
must round-trip through the JSON file; and every tuned plan must stay
numerically equal to the `direct` backend oracle across paper_suite().
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import BACKENDS, apply_stencil
from repro.core.stencil import make_stencil, paper_suite
from repro.kernels.dispatch import applicable_backends
from repro.tuner import (Plan, PlanCache, autotune, candidate_plans, plan_for,
                         plan_key, shape_bucket, spec_fingerprint, static_cost,
                         tuned_apply, tuned_apply_batched)
from repro.tuner.plan import PLAN_SCHEMA, PlanKey, mesh_desc


def _x(spec, dims, rng, dtype=jnp.float32):
    shape = tuple(s + 2 * spec.radius for s in dims)
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# plans and keys
# ---------------------------------------------------------------------------

def test_plan_dict_roundtrip():
    p = Plan(backend="sptc", L=8, fuse_rows=True, star_fast_path=False)
    assert Plan.from_dict(p.to_dict()) == p


def test_plan_key_encode_decode_roundtrip():
    key = PlanKey(spec_fp="abc123", bucket=(64, 128), dtype="float32",
                  device="cpu")
    assert PlanKey.decode(key.encode()) == key


def test_spec_fingerprint_is_content_hash():
    a = make_stencil("box", 2, 2, seed=1)
    b = make_stencil("box", 2, 2, seed=1)     # same content, new object
    c = make_stencil("box", 2, 2, seed=2)
    assert spec_fingerprint(a) == spec_fingerprint(b)
    assert spec_fingerprint(a) != spec_fingerprint(c)


def test_shape_bucket_rounds_up_to_pow2():
    assert shape_bucket((37, 41)) == (64, 64)
    assert shape_bucket((64,)) == (64,)
    assert shape_bucket((65, 1)) == (128, 1)
    # nearby sizes share a plan; the key still splits on dtype and device
    spec = make_stencil("star", 2, 1, seed=0)
    assert plan_key(spec, (60, 60), jnp.float32) == \
        plan_key(spec, (64, 33), jnp.float32)
    assert plan_key(spec, (60, 60), jnp.float32) != \
        plan_key(spec, (60, 60), jnp.bfloat16)


# ---------------------------------------------------------------------------
# candidate enumeration + cost model
# ---------------------------------------------------------------------------

def test_candidates_are_applicable_and_valid():
    for spec in paper_suite():
        plans = candidate_plans(spec)
        assert plans
        ok = applicable_backends(spec)
        for p in plans:
            assert p.backend in ok and p.backend in BACKENDS
            assert p.L % 2 == 0 and p.L >= 2 * spec.radius + 2
            assert static_cost(spec, p) > 0


def test_cost_mode_autotune_builds_nothing():
    spec = make_stencil("box", 2, 3, seed=0)
    calls = []
    res = autotune(spec, (70, 70), mode="cost",
                   engine_factory=lambda *a: calls.append(a))
    assert res.mode == "cost" and not calls
    assert res.plan in candidate_plans(spec)
    # the model prefers the SpTC path (K/2 MACs on the matrix unit) for a
    # large box stencil — the paper's headline claim
    assert res.plan.backend == "sptc"


# ---------------------------------------------------------------------------
# cache behavior: plan hits, zero re-jit
# ---------------------------------------------------------------------------

def test_repeat_apply_hits_cache_no_rejit(rng):
    spec = make_stencil("box", 2, 2, seed=3)
    x = _x(spec, (30, 34), rng)
    cache = PlanCache()
    y1 = tuned_apply(spec, x, cache=cache, mode="cost")
    assert cache.stats.plan_misses == 1 and cache.stats.tunes == 1
    builds = cache.stats.engine_builds
    assert builds == 1
    y2 = tuned_apply(spec, x, cache=cache, mode="cost")
    assert cache.stats.engine_builds == builds      # no new engine
    assert cache.stats.plan_hits >= 1 and cache.stats.tunes == 1
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # the jitted executable was not re-traced either
    plan = plan_for(spec, x.shape, x.dtype, cache=cache, mode="cost")
    eng = cache.engine(spec, plan)
    if hasattr(eng._fn, "_cache_size"):
        assert eng._fn._cache_size() == 1


def test_apply_stencil_reuses_engine_across_calls(rng):
    """The seed's dead `_cached_engine` replacement: the functional entry
    point must not build a fresh engine per call."""
    from repro.tuner.cache import default_cache
    spec = make_stencil("star", 2, 2, seed=8)
    x = _x(spec, (26, 28), rng)
    apply_stencil(spec, x, backend="gemm")
    builds = default_cache().stats.engine_builds
    apply_stencil(spec, x, backend="gemm")
    assert default_cache().stats.engine_builds == builds


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_plan_persistence_roundtrip(tmp_path, rng):
    path = tmp_path / "plans.json"
    spec = make_stencil("box", 2, 1, seed=5)
    x = _x(spec, (22, 26), rng)

    cache_a = PlanCache(path=path)
    plan = plan_for(spec, x.shape, x.dtype, cache=cache_a, mode="cost")
    assert path.exists() and cache_a.stats.saves >= 1

    cache_b = PlanCache(path=path)                 # fresh process, warm file
    assert cache_b.stats.loads == 1 and len(cache_b) == len(cache_a)
    assert plan_for(spec, x.shape, x.dtype, cache=cache_b) == plan
    assert cache_b.stats.tunes == 0                # no retune after reload


def test_persistence_ignores_corrupt_file(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text("{not json")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        cache = PlanCache(path=path)
    assert len(cache) == 0 and cache.stats.loads == 0


# ---------------------------------------------------------------------------
# schema forward/backward compatibility (PR-8 satellite)
# ---------------------------------------------------------------------------

def test_plan_from_dict_tolerates_unknown_and_missing_fields():
    d = Plan(backend="gemm", L=4).to_dict()
    d["novel_future_knob"] = 123                 # unknown: ignored
    assert Plan.from_dict(d) == Plan(backend="gemm", L=4)
    legacy = {"backend": "sptc", "L": 8}         # schema-1: fields default
    p = Plan.from_dict(legacy)
    assert p == Plan(backend="sptc", L=8, fuse_rows=False,
                     star_fast_path=True, temporal_steps=1)
    with pytest.raises(ValueError, match="schema"):
        Plan.from_dict({"schema": PLAN_SCHEMA + 1, "backend": "gemm", "L": 4})


def test_plan_key_decodes_v1_and_tolerates_unknown_fields():
    key = PlanKey(spec_fp="abc", bucket=(64, 32), dtype="float32",
                  device="cpu")
    legacy = "spec=abc;shape=64x32;dtype=float32;dev=cpu"
    assert PlanKey.decode(legacy) == key         # v1: coeff/steps default
    assert PlanKey.decode(key.encode() + ";future=knob") == key
    with pytest.raises(ValueError, match="newer"):
        PlanKey.decode(f"v{PLAN_SCHEMA + 1};" + legacy)
    with pytest.raises(ValueError, match="prefix"):
        PlanKey.decode("garbage")


def test_plan_key_univ_roundtrip_and_v2_back_compat():
    key = PlanKey(spec_fp="abc", bucket=(64, 32), dtype="float32",
                  device="cpu", univ="jnp+pallas")
    assert PlanKey.decode(key.encode()) == key
    # a pre-v3 key carries no universe field: decodes as plain-jnp tuning
    v2 = "v2;spec=abc;shape=64x32;dtype=float32;dev=cpu;coeff=const;steps=1"
    assert PlanKey.decode(v2).univ == "jnp"


def test_pallas_universe_plans_cannot_poison_jnp_cache(tmp_path, monkeypatch):
    """A plan tuned with the Pallas backends forced in (interpret-mode
    correctness sweep) must never be served to a plain-CPU process."""
    spec = make_stencil("box", 2, 1, seed=6)
    monkeypatch.delenv("REPRO_TUNER_INCLUDE_PALLAS", raising=False)
    plain = plan_key(spec, (20, 20), jnp.float32)
    monkeypatch.setenv("REPRO_TUNER_INCLUDE_PALLAS", "1")
    forced = plan_key(spec, (20, 20), jnp.float32)
    assert plain.univ == "jnp" and forced.univ == "jnp+pallas"
    assert plain.encode() != forced.encode()
    cache = PlanCache(path=tmp_path / "plans.json")
    cache.store(forced, Plan(backend="pallas_sptc", L=4))
    monkeypatch.delenv("REPRO_TUNER_INCLUDE_PALLAS")
    assert cache.lookup(plan_key(spec, (20, 20), jnp.float32)) is None
    assert cache.lookup(forced) == Plan(backend="pallas_sptc", L=4)


def test_plan_key_mesh_roundtrip_and_v3_back_compat():
    key = PlanKey(spec_fp="abc", bucket=(64, 32), dtype="float32",
                  device="cpu", mesh="4x2")
    assert PlanKey.decode(key.encode()) == key
    # a pre-v4 key carries no mesh field: decodes as single-device tuning
    v3 = ("v3;spec=abc;shape=64x32;dtype=float32;dev=cpu;coeff=const;"
          "steps=1;univ=jnp")
    assert PlanKey.decode(v3).mesh == "1"
    assert PLAN_SCHEMA == 4 and key.encode().startswith("v4;")


def test_mesh_desc_canonicalization():
    # everything single-device-shaped collapses to the SAME key as None
    for trivial in (None, 1, (1,), (1, 1), "1", "1x1"):
        assert mesh_desc(trivial) == "1", trivial
    assert mesh_desc(8) == "8"
    assert mesh_desc((4, 2)) == "4x2"
    assert mesh_desc("4x2") == "4x2"
    assert mesh_desc((4, 1)) == "4"              # extent-1 axes dropped

    class FakeMesh:                              # jax.sharding.Mesh shape
        axis_names = ("sp0", "sp1")
        shape = {"sp0": 4, "sp1": 2}
    assert mesh_desc(FakeMesh()) == "4x2"
    with pytest.raises(ValueError, match=">= 1"):
        mesh_desc((4, 0))
    with pytest.raises(ValueError, match="unparseable"):
        mesh_desc("4xpotato")
    with pytest.raises(TypeError, match="mesh must be"):
        mesh_desc(3.5)


def test_sharded_plans_cannot_poison_single_device_cache(tmp_path):
    """Mirror of the universe-poisoning fence: a plan tuned for a 4x2
    block partition must never be served to a single-device lookup, and
    vice versa — the geometries want different backends/tile sizes."""
    spec = make_stencil("box", 2, 1, seed=6)
    plain = plan_key(spec, (20, 20), jnp.float32)
    sharded = plan_key(spec, (20, 20), jnp.float32, mesh=(4, 2))
    assert plain.mesh == "1" and sharded.mesh == "4x2"
    assert plain.encode() != sharded.encode()
    cache = PlanCache(path=tmp_path / "plans.json")
    cache.store(sharded, Plan(backend="sptc", L=8))
    assert cache.lookup(plain) is None
    assert cache.lookup(sharded) == Plan(backend="sptc", L=8)
    # and the sharded entry round-trips through the JSON file
    reloaded = PlanCache(path=tmp_path / "plans.json")
    assert reloaded.lookup(sharded) == Plan(backend="sptc", L=8)
    # a degenerate all-1 mesh IS single-device: shares the plain entry
    assert plan_key(spec, (20, 20), jnp.float32, mesh=(1, 1)) == plain


def test_batched_accepts_generators_and_rejects_junk(rng):
    """_validate_batch used to iterate generators lazily and fail deep in
    jnp.stack with an opaque error; now it materializes them loudly."""
    spec = make_stencil("star", 2, 1, seed=2)
    xs = [_x(spec, (18, 18), rng) for _ in range(3)]
    stacked = tuned_apply_batched(spec, jnp.stack(xs), mode="cost")
    via_gen = tuned_apply_batched(spec, (x for x in xs), mode="cost")
    np.testing.assert_allclose(np.asarray(via_gen), np.asarray(stacked),
                               rtol=1e-6, atol=1e-6)
    with pytest.raises(TypeError, match="iterable of per-job arrays"):
        tuned_apply_batched(spec, object(), mode="cost")
    with pytest.raises(ValueError, match="empty"):
        tuned_apply_batched(spec, iter([]), mode="cost")


def test_plan_key_splits_on_coeff_and_steps():
    spec = make_stencil("box", 2, 1, seed=1)
    base = plan_key(spec, (20, 20), jnp.float32)
    assert base.coeff == "const" and base.steps == 1
    k2 = plan_key(spec, (20, 20), jnp.float32, temporal_steps=2)
    c = np.ones((18, 18, 3, 3))
    var = plan_key(spec, (20, 20), jnp.float32, coefficients=c)
    assert len({base.encode(), k2.encode(), var.encode()}) == 3
    assert var.coeff.startswith("var-")


def test_pre_pr8_cache_file_round_trips(tmp_path, rng):
    """A v1 cache file (unversioned keys, schema-1 plans) still hits —
    ``tuned_apply`` must not retune against a pre-PR-8 persisted cache."""
    spec = make_stencil("box", 2, 1, seed=5)
    x = _x(spec, (22, 26), rng)
    key = plan_key(spec, x.shape, x.dtype)
    legacy_key = (f"spec={key.spec_fp};"
                  f"shape={'x'.join(str(s) for s in key.bucket)};"
                  f"dtype={key.dtype};dev={key.device}")
    legacy_plan = {"backend": "gemm", "L": 4, "fuse_rows": False,
                   "star_fast_path": True}
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({"version": 1,
                                "plans": {legacy_key: legacy_plan}}))
    cache = PlanCache(path=path)
    assert len(cache) == 1 and cache.stats.loads == 1
    got = tuned_apply(spec, x, cache=cache, mode="cost")
    assert cache.stats.tunes == 0                # the legacy entry hit
    want = apply_stencil(spec, x, backend="direct")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_cache_skips_corrupt_and_future_entries_with_warning(tmp_path):
    spec = make_stencil("box", 1, 1, seed=2)
    good_key = plan_key(spec, (40,), jnp.float32).encode()
    payload = {"version": 2, "plans": {
        good_key: Plan(backend="gemm", L=4).to_dict(),
        "garbage-key": Plan(backend="gemm", L=4).to_dict(),
        f"v{PLAN_SCHEMA + 1};{good_key}": Plan(backend="gemm", L=4).to_dict(),
        good_key.replace("steps=1", "steps=2"):
            {"schema": PLAN_SCHEMA + 1, "backend": "gemm", "L": 4},
    }}
    path = tmp_path / "plans.json"
    path.write_text(json.dumps(payload))
    with pytest.warns(RuntimeWarning, match="skipping entry"):
        cache = PlanCache(path=path)
    assert len(cache) == 1 and cache.stats.skipped_entries == 3
    assert cache.lookup(plan_key(spec, (40,), jnp.float32)) is not None


def test_future_versioned_file_is_ignored_whole(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({"version": 99, "plans": {}}))
    with pytest.warns(RuntimeWarning, match="version"):
        cache = PlanCache(path=path)
    assert len(cache) == 0 and cache.stats.loads == 0


def test_save_merges_concurrent_writers(tmp_path):
    """Two caches sharing one file converge on the union of their plans."""
    path = tmp_path / "plans.json"
    spec_a = make_stencil("box", 1, 1, seed=3)
    spec_b = make_stencil("box", 1, 2, seed=4)
    key_a = plan_key(spec_a, (40,), jnp.float32)
    key_b = plan_key(spec_b, (40,), jnp.float32)
    cache_a = PlanCache(path=path)
    cache_b = PlanCache(path=path)
    cache_a.store(key_a, Plan(backend="gemm", L=4))      # writes the file
    cache_b.store(key_b, Plan(backend="sptc", L=6))      # merges, then writes
    assert len(cache_b) == 2 and cache_b.stats.merges == 1
    fresh = PlanCache(path=path)
    assert len(fresh) == 2
    assert fresh.lookup(key_a) == Plan(backend="gemm", L=4)
    assert fresh.lookup(key_b) == Plan(backend="sptc", L=6)


def test_save_conflicts_prefer_memory(tmp_path):
    path = tmp_path / "plans.json"
    spec = make_stencil("box", 1, 1, seed=3)
    key = plan_key(spec, (40,), jnp.float32)
    cache_a = PlanCache(path=path)
    cache_b = PlanCache(path=path)
    cache_a.store(key, Plan(backend="gemm", L=4))
    cache_b.store(key, Plan(backend="sptc", L=6))        # same key: b wins b's
    assert PlanCache(path=path).lookup(key) == Plan(backend="sptc", L=6)


# ---------------------------------------------------------------------------
# correctness: tuned plans == direct oracle across the paper suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["cost"])
def test_tuned_matches_direct_over_paper_suite(mode, rng):
    cache = PlanCache()
    for spec in paper_suite():
        dims = {1: (131,), 2: (24, 27)}[spec.ndim]
        x = _x(spec, dims, rng)
        got = tuned_apply(spec, x, cache=cache, mode=mode)
        want = apply_stencil(spec, x, backend="direct")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_every_candidate_plan_matches_direct(rng):
    """Stronger than the tuned pick: ALL candidates are valid executions."""
    spec = make_stencil("box", 2, 2, seed=6)
    x = _x(spec, (21, 23), rng)
    cache = PlanCache()
    want = np.asarray(apply_stencil(spec, x, backend="direct"))
    for plan in candidate_plans(spec):
        got = np.asarray(cache.engine(spec, plan)(x))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5,
                                   err_msg=str(plan))


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

def test_tuned_apply_temporal_matches_repeated_direct(rng):
    spec = make_stencil("star", 2, 1, seed=12)
    x = _x(spec, (20, 22), rng)                  # dims + 2r; k=2 needs 2·(2r)
    x = jnp.asarray(np.pad(np.asarray(x), spec.radius))
    cache = PlanCache()
    got = tuned_apply(spec, x, cache=cache, mode="cost", temporal_steps=2)
    want = apply_stencil(spec, apply_stencil(spec, x, backend="direct"),
                         backend="direct")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # the k=2 plan keys separately from the single-step plan
    assert cache.stats.tunes == 1
    tuned_apply(spec, x, cache=cache, mode="cost")
    assert cache.stats.tunes == 2


def test_tuned_apply_variable_coefficients(rng):
    from repro.core.engine import StencilEngine
    spec = make_stencil("box", 2, 1, seed=13)
    dims = (10, 12)
    c = rng.normal(size=dims + (3, 3))
    x = jnp.asarray(rng.normal(size=(12, 14)), jnp.float32)
    cache = PlanCache()
    got = tuned_apply(spec, x, cache=cache, mode="cost", coefficients=c)
    want = StencilEngine(spec, backend="direct", coefficients=c)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # var plans tune per content fingerprint, apart from the const plan
    assert cache.stats.tunes == 1
    tuned_apply(spec, x, cache=cache, mode="cost", coefficients=c)
    assert cache.stats.tunes == 1                # same field: cache hit
    tuned_apply(spec, x, cache=cache, mode="cost")
    assert cache.stats.tunes == 2                # const plan is separate


def test_batched_matches_per_instance(rng):
    spec = make_stencil("star", 2, 1, seed=7)
    xs = jnp.asarray(rng.normal(size=(5, 40, 44)), jnp.float32)
    cache = PlanCache()
    got = tuned_apply_batched(spec, xs, cache=cache, mode="cost")
    assert got.shape == (5, 38, 42)
    for i in range(xs.shape[0]):
        want = apply_stencil(spec, xs[i], backend="direct")
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_batched_accepts_sequence_of_same_shape_jobs(rng):
    spec = make_stencil("box", 1, 1, seed=9)
    xs = [jnp.asarray(rng.normal(size=(50,)), jnp.float32) for _ in range(3)]
    cache = PlanCache()
    got = tuned_apply_batched(spec, xs, cache=cache, mode="cost")
    want = tuned_apply_batched(spec, jnp.stack(xs), cache=cache, mode="cost")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_rejects_mismatched_shapes(rng):
    """The old behavior silently assumed one shape; now the error names
    the offending jobs and their shapes."""
    spec = make_stencil("star", 2, 1, seed=7)
    xs = [jnp.zeros((34, 34)), jnp.zeros((34, 34)), jnp.zeros((36, 34))]
    with pytest.raises(ValueError) as ei:
        tuned_apply_batched(spec, xs, cache=PlanCache(), mode="cost")
    msg = str(ei.value)
    assert "(34, 34)" in msg and "(36, 34)" in msg and "job 2" in msg


def test_batched_rejects_mismatched_dtypes_and_bad_rank(rng):
    spec = make_stencil("star", 2, 1, seed=7)
    cache = PlanCache()
    xs = [jnp.zeros((34, 34), jnp.float32), jnp.zeros((34, 34), jnp.bfloat16)]
    with pytest.raises(ValueError, match="dtype"):
        tuned_apply_batched(spec, xs, cache=cache, mode="cost")
    with pytest.raises(ValueError, match="empty"):
        tuned_apply_batched(spec, [], cache=cache, mode="cost")
    with pytest.raises(ValueError, match="B, \\*spatial"):
        tuned_apply_batched(spec, jnp.zeros((34, 34)), cache=cache,
                            mode="cost")
    with pytest.raises(ValueError, match="halo"):
        tuned_apply_batched(spec, jnp.zeros((4, 2, 34)), cache=cache,
                            mode="cost")


def test_batched_reuses_compiled_program(rng):
    spec = make_stencil("box", 1, 1, seed=9)
    xs = jnp.asarray(rng.normal(size=(4, 66)), jnp.float32)
    cache = PlanCache()
    tuned_apply_batched(spec, xs, cache=cache, mode="cost")
    builds = cache.stats.engine_builds
    tuned_apply_batched(spec, xs, cache=cache, mode="cost")
    assert cache.stats.engine_builds == builds


# ---------------------------------------------------------------------------
# timing mode (small, smoke-level — CI stays fast)
# ---------------------------------------------------------------------------

def test_timing_mode_smoke(rng):
    spec = make_stencil("box", 1, 1, seed=10)
    x = _x(spec, (96,), rng)
    res = autotune(spec, x.shape, x.dtype, mode="time", warmup=1, iters=2)
    assert res.mode == "time"
    assert res.plan in candidate_plans(spec)
    assert any(c.error is None and c.score > 0 for c in res.candidates)


def test_time_mode_prunes_losing_candidate_engines(rng):
    """A timed tune must not leave every losing candidate's jitted engine
    resident — only the winner (and pre-existing engines) survive."""
    spec = make_stencil("box", 1, 1, seed=11)
    x = _x(spec, (80,), rng)
    cache = PlanCache()
    plan = plan_for(spec, x.shape, x.dtype, cache=cache, mode="time", iters=2)
    assert cache.engine_plans(spec) == frozenset({plan})


def test_autotune_rejects_bad_mode():
    with pytest.raises(ValueError):
        autotune(make_stencil("box", 1, 1), (32,), mode="fastest")
