"""repro.vet tests: fixture corpus (bad snippets flagged, clean twins
accepted), invariant failure injection, baseline mechanics, CLI exit
codes, and clean-tree acceptance of the shipped sources."""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.sparsify import (apply_col_perm, encode_24,
                                 sparsify_stencil_kernel, strided_swap_perm)
from repro.core.transform import kernel_matrix
from repro.vet import code as vet_code
from repro.vet import invariants
from repro.vet.baseline import Baseline, BaselineEntry
from repro.vet.cli import main as vet_main
from repro.vet.config import VetConfig, load_config
from repro.vet.findings import Finding, counts_by_severity, worst_severity

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "vet_fixtures"


def cfg_for(root: Path) -> VetConfig:
    cfg = VetConfig()
    cfg.root = root
    return cfg


def rules_hit(path: Path) -> set:
    findings = vet_code.check_file(cfg_for(FIXTURES), path)
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# code analyzer: fixture corpus
# ---------------------------------------------------------------------------

BAD_FIXTURES = [
    ("serving/bad_jit_per_call.py", "code-jit-per-call", 2),
    ("serving/bad_host_sync.py", "code-host-sync", 3),
    ("serving/bad_lock_discipline.py", "code-lock-discipline", 1),
    ("serving/bad_lock_discipline.py", "code-locked-suffix", 1),
    ("tuner/bad_nondet_key.py", "code-nondet-key", 2),
]

CLEAN_FIXTURES = [
    "serving/clean_jit_memoized.py",
    "serving/clean_host_sync.py",
    "serving/clean_lock_discipline.py",
    "tuner/clean_nondet_key.py",
]


@pytest.mark.parametrize("rel,rule,n", BAD_FIXTURES)
def test_bad_fixture_is_flagged(rel, rule, n):
    findings = vet_code.check_file(cfg_for(FIXTURES), FIXTURES / rel)
    hits = [f for f in findings if f.rule == rule]
    assert len(hits) == n, (rel, rule, [f.format() for f in findings])
    for f in hits:
        assert f.line > 0 and f.symbol and f.message


@pytest.mark.parametrize("rel", CLEAN_FIXTURES)
def test_clean_twin_is_accepted(rel):
    findings = vet_code.check_file(cfg_for(FIXTURES), FIXTURES / rel)
    assert findings == [], [f.format() for f in findings]


def test_rules_only_fire_in_hot_modules(tmp_path):
    # the same bad code outside serving/tuner directories is not flagged
    cold = tmp_path / "models" / "bad.py"
    cold.parent.mkdir()
    cold.write_text((FIXTURES / "serving/bad_jit_per_call.py").read_text())
    assert vet_code.check_file(cfg_for(tmp_path), cold) == []


def test_severity_off_disables_a_code_rule():
    cfg = cfg_for(FIXTURES)
    cfg.severity["code-host-sync"] = "off"
    findings = vet_code.check_file(cfg, FIXTURES / "serving/bad_host_sync.py")
    assert all(f.rule != "code-host-sync" for f in findings)


def test_unparseable_file_yields_parse_finding(tmp_path):
    bad = tmp_path / "serving" / "oops.py"
    bad.parent.mkdir()
    bad.write_text("def broken(:\n")
    findings = vet_code.check_file(cfg_for(tmp_path), bad)
    assert [f.rule for f in findings] == ["code-parse"]
    assert findings[0].severity == "error"


# ---------------------------------------------------------------------------
# invariant analyzer: failure injection
# ---------------------------------------------------------------------------

def test_invariant_sweep_is_clean_on_shipped_transform():
    cfg = VetConfig()
    cfg.invariant_radii = [1, 2]          # trimmed sweep for test speed
    assert invariants.run(cfg) == []


def test_injected_band_corruption_is_found():
    cfg = VetConfig()
    w = np.array([1.0, 2.0, 1.0])
    K = kernel_matrix(w, L=4, pad_width=True)
    K[0, -1] = 7.0                         # off-band garbage
    fs = invariants.check_kernel_matrix(cfg, K, w, 4, "inj")
    assert any(f.rule == "invariant-banded" for f in fs)


def test_injected_bad_permutation_is_found():
    cfg = VetConfig()
    perm = strided_swap_perm(4).copy()
    perm[0], perm[1] = perm[1], perm[0]    # break the involution
    fs = invariants.check_involution(cfg, perm, "inj")
    assert any(f.rule == "invariant-involution" for f in fs)
    fs = invariants.check_involution(cfg, np.zeros(8, dtype=int), "inj")
    assert any("not a permutation" in f.message for f in fs)


def test_injected_dense_segment_is_found():
    cfg = VetConfig()
    Kp = np.zeros((2, 8))
    Kp[0, :3] = 1.0                        # 3 non-zeros in one 4-segment
    fs = invariants.check_24_pattern(cfg, Kp, "inj")
    assert any(f.rule == "invariant-24" for f in fs)


def test_injected_meta_corruption_is_found():
    cfg = VetConfig()
    w = np.array([1.0, 2.0, 1.0])
    K = kernel_matrix(w, L=4, pad_width=True)
    Kp = apply_col_perm(K, strided_swap_perm(4))
    sp = encode_24(Kp)
    bad_meta = np.asarray(sp.meta).copy()
    bad_meta[0, 0], bad_meta[0, 1] = bad_meta[0, 1], bad_meta[0, 0]
    import dataclasses
    corrupted = dataclasses.replace(sp, meta=bad_meta)
    fs = invariants.check_sparse24(cfg, corrupted, None, "inj")
    assert any(f.rule == "invariant-meta" for f in fs)


def test_injected_value_corruption_fails_roundtrip():
    cfg = VetConfig()
    sk = sparsify_stencil_kernel(np.array([1.0, 2.0, 1.0]), L=4)
    Kp = apply_col_perm(kernel_matrix(np.array([1.0, 2.0, 1.0]), L=4,
                                      pad_width=True), sk.perm)
    sp = encode_24(Kp)
    import dataclasses
    bad_vals = np.asarray(sp.values).copy()
    bad_vals[0, 0] += 1.0
    corrupted = dataclasses.replace(sp, values=bad_vals)
    fs = invariants.check_sparse24(cfg, corrupted, Kp, "inj")
    assert any(f.rule == "invariant-roundtrip" for f in fs)


# ---------------------------------------------------------------------------
# findings / baseline mechanics
# ---------------------------------------------------------------------------

def test_finding_roundtrip_and_severity_order():
    f = Finding(rule="code-host-sync", severity="warning",
                path="src/x.py", line=3, symbol="A.b", message="m")
    assert Finding.from_dict(f.to_dict()) == f
    assert "src/x.py:3" in f.format()
    e = Finding(rule="r", severity="error", path="p", line=0,
                symbol="s", message="m")
    assert worst_severity([f, e]) == "error"
    assert counts_by_severity([f, e]) == {"error": 1, "warning": 1, "info": 0}
    with pytest.raises(ValueError):
        Finding(rule="r", severity="fatal", path="p", line=0,
                symbol="s", message="m")


def test_baseline_split_suppresses_and_reports_unused(tmp_path):
    f1 = Finding(rule="r1", severity="error", path="a.py", line=10,
                 symbol="f", message="m")
    f2 = Finding(rule="r2", severity="error", path="b.py", line=20,
                 symbol="g", message="m")
    bl = Baseline([BaselineEntry(rule="r1", path="a.py", symbol="f",
                                 reason="known"),
                   BaselineEntry(rule="zzz", path="gone.py", symbol="h")])
    new, suppressed, unused = bl.split([f1, f2])
    assert new == [f2] and suppressed == [f1]
    assert [e.rule for e in unused] == ["zzz"]
    # line drift does not invalidate entries
    import dataclasses
    moved = dataclasses.replace(f1, line=99)
    assert bl.split([moved])[1] == [moved]
    # save/load round-trip keeps reasons
    p = tmp_path / "bl.json"
    bl.save(p)
    again = Baseline.load(p)
    assert {e.key(): e.reason for e in again.entries} == \
           {e.key(): e.reason for e in bl.entries}


def test_load_config_reads_pyproject(tmp_path):
    (tmp_path / "pyproject.toml").write_text(
        "[tool.repro-vet]\n"
        'baseline = "custom.json"\n'
        'hot_path_modules = ["serving"]\n'
        "invariant_radii = [1]\n"
        "[tool.repro-vet.severity]\n"
        'code-host-sync = "error"\n'
        "[tool.repro-vet.lowering]\n"
        'backends = ["gemm"]\n'
        "[tool.repro-vet.lowering.budgets.gemm]\n"
        "gather = 2\n")
    cfg = load_config(pyproject=tmp_path / "pyproject.toml")
    assert cfg.baseline == "custom.json"
    assert cfg.hot_path_modules == ["serving"]
    assert cfg.invariant_radii == [1]
    assert cfg.severity_of("code-host-sync") == "error"
    assert cfg.lowering_backends == ["gemm"]
    assert cfg.lowering_budgets["gemm"]["gather"] == 2
    assert cfg.baseline_path() == tmp_path / "custom.json"


def test_repo_pyproject_configures_vet():
    cfg = load_config(pyproject=REPO / "pyproject.toml")
    assert cfg.severity_of("code-host-sync") == "warning"
    assert set(cfg.lowering_backends) == {"gemm", "sptc"}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_flags_fixture_corpus_nonzero(capsys):
    rc = vet_main(["--analyzers", "code", "--no-baseline",
                   "--root", str(FIXTURES), str(FIXTURES)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "code-jit-per-call" in out and "code-lock-discipline" in out


def test_cli_clean_twin_dir_exits_zero(capsys, tmp_path):
    hot = tmp_path / "serving"
    hot.mkdir()
    for rel in CLEAN_FIXTURES[:3]:
        (hot / Path(rel).name).write_text((FIXTURES / rel).read_text())
    rc = vet_main(["--analyzers", "code", "--no-baseline",
                   "--root", str(tmp_path), str(tmp_path)])
    assert rc == 0


def test_cli_json_report_shape(capsys):
    rc = vet_main(["--analyzers", "code", "--no-baseline", "--json",
                   "--root", str(FIXTURES), str(FIXTURES)])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert {"findings", "suppressed", "unused_baseline", "counts"} \
           <= set(report)
    assert report["counts"]["error"] >= 1
    rules = {f["rule"] for f in report["findings"]}
    assert "code-nondet-key" in rules


def test_cli_write_baseline_then_pass(tmp_path, capsys):
    hot = tmp_path / "serving"
    hot.mkdir()
    (hot / "bad.py").write_text(
        (FIXTURES / "serving/bad_jit_per_call.py").read_text())
    bl = tmp_path / "bl.json"
    rc = vet_main(["--analyzers", "code", "--root", str(tmp_path),
                   "--baseline", str(bl), "--write-baseline",
                   str(tmp_path)])
    assert rc == 0 and bl.exists()
    capsys.readouterr()
    rc = vet_main(["--analyzers", "code", "--root", str(tmp_path),
                   "--baseline", str(bl), str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "suppressed by baseline" in out


def test_cli_unknown_analyzer_usage_error(capsys):
    assert vet_main(["--analyzers", "nope"]) == 2
    assert "unknown analyzer" in capsys.readouterr().err


def test_cli_missing_path_usage_error(capsys):
    rc = vet_main(["--analyzers", "code", "/definitely/not/here"])
    assert rc == 2


# ---------------------------------------------------------------------------
# clean-tree acceptance: the shipped sources pass modulo the baseline
# ---------------------------------------------------------------------------

def test_shipped_tree_passes_code_analyzer_modulo_baseline():
    cfg = load_config(pyproject=REPO / "pyproject.toml")
    findings = vet_code.run(cfg, [REPO / "src" / "repro"])
    baseline = Baseline.load(cfg.baseline_path())
    new, suppressed, _unused = baseline.split(findings)
    errors = [f for f in new if f.severity == "error"]
    assert errors == [], [f.format() for f in errors]
    # the two intentional worker-thread syncs are baselined, not silenced
    assert {f.symbol for f in suppressed} == {
        "GenerateDriver._run_batch", "StencilDriver._run_batch"}


def test_shipped_invariants_hold_over_registry_sweep():
    cfg = load_config(pyproject=REPO / "pyproject.toml")
    assert invariants.run(cfg) == []
