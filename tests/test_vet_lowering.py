"""Lowering analyzer tests: the shipped engines certify zero-overhead,
and tightened budgets / synthetic overhead are detected."""
from __future__ import annotations

import pytest

from repro.core.stencil import make_stencil
from repro.vet import lowering
from repro.vet.config import VetConfig


def test_n_applications_matches_engine_structure():
    star = make_stencil("star", 2, 1, seed=0)
    box = make_stencil("box", 2, 1, seed=0)
    assert lowering.n_applications(star, fused=False) == 2
    assert lowering.n_applications(box, fused=True) == 1
    assert lowering.n_applications(box, fused=False) == 3   # 2r+1 rows
    assert lowering.n_applications(make_stencil("star", 1, 2, seed=0),
                                   fused=False) == 1


def test_shipped_engines_certify_zero_overhead():
    cfg = VetConfig()
    findings, verdict = lowering.run(cfg)
    assert findings == [], [f.format() for f in findings]
    assert set(verdict) == {"stencil_gemm", "sptc_spmm"}
    for kernel, v in verdict.items():
        assert v["certified"], (kernel, v)
        assert v["traces"] == 1
        for probe, counts in v["probes"].items():
            # the intrinsic im2col window read is the ONLY gather
            assert counts["gather"] <= counts["dot"], (probe, counts)
            assert counts["dynamic-slice"] == 0, (probe, counts)
    # sparse parity: sptc lowers to the same overhead profile as gemm
    gemm = {k.split("/", 1)[1]: v
            for k, v in verdict["stencil_gemm"]["probes"].items()}
    sptc = {k.split("/", 1)[1]: v
            for k, v in verdict["sptc_spmm"]["probes"].items()}
    assert gemm == sptc


def test_tightened_budget_produces_findings():
    cfg = VetConfig()
    cfg.lowering_backends = ["gemm"]
    cfg.lowering_budgets["gemm"]["gather"] = 0     # forbid the window read
    findings, verdict = lowering.run(cfg)
    assert any(f.rule == "lowering-hot-gather" for f in findings)
    assert not verdict["stencil_gemm"]["certified"]


def test_hot_counts_covers_all_overhead_ops():
    eng_spec = make_stencil("star", 2, 1, seed=7)
    from repro.core.engine import StencilEngine
    report = lowering.lower_engine(StencilEngine(eng_spec, backend="gemm"),
                                   (34, 34))
    counts = lowering.hot_counts(report)
    assert set(counts) == set(lowering.OVERHEAD_OPS) | {"dot"}
    assert counts["dot"] == 2
    assert report.histogram()      # non-empty backward closure


def test_trace_count_is_one_for_fixed_shape():
    from repro.core.engine import StencilEngine
    eng = StencilEngine(make_stencil("star", 2, 1, seed=7), backend="sptc")
    assert lowering.trace_count(eng, (20, 20), calls=3) == 1
