"""Lowering analyzer tests: the shipped engines certify zero-overhead,
and tightened budgets / synthetic overhead are detected."""
from __future__ import annotations

import pytest

from repro.core.stencil import make_stencil
from repro.vet import lowering
from repro.vet.config import VetConfig


def test_n_applications_matches_engine_structure():
    star = make_stencil("star", 2, 1, seed=0)
    box = make_stencil("box", 2, 1, seed=0)
    assert lowering.n_applications(star, fused=False) == 2
    assert lowering.n_applications(box, fused=True) == 1
    assert lowering.n_applications(box, fused=False) == 3   # 2r+1 rows
    assert lowering.n_applications(make_stencil("star", 1, 2, seed=0),
                                   fused=False) == 1


def test_shipped_engines_certify_zero_overhead():
    cfg = VetConfig()
    findings, verdict = lowering.run(cfg)
    assert findings == [], [f.format() for f in findings]
    assert set(verdict) == {"stencil_gemm", "sptc_spmm", "sptc_spmm_fused"}
    for kernel in ("stencil_gemm", "sptc_spmm"):
        v = verdict[kernel]
        assert v["certified"], (kernel, v)
        assert v["traces"] == 1
        for probe, counts in v["probes"].items():
            # the intrinsic im2col window read is the ONLY gather
            assert counts["gather"] <= counts["dot"], (probe, counts)
            assert counts["dynamic-slice"] == 0, (probe, counts)
    # sparse parity: sptc lowers to the same overhead profile as gemm
    gemm = {k.split("/", 1)[1]: v
            for k, v in verdict["stencil_gemm"]["probes"].items()}
    sptc = {k.split("/", 1)[1]: v
            for k, v in verdict["sptc_spmm"]["probes"].items()}
    assert gemm == sptc


def test_fused_pallas_kernel_certifies_zero_overhead():
    """The fused SpTC program owns the swap and the windowing: outside the
    pallas_call there must be no gathers at all and no dynamic slicing."""
    findings, probes = lowering.analyze_pallas_fused(VetConfig())
    assert findings == [], [f.format() for f in findings]
    assert probes                                    # both registry probes ran
    for probe, counts in probes.items():
        assert probe.startswith("sptc_spmm_fused/"), probe
        assert counts["gather"] == 0, (probe, counts)
        assert counts.get("dynamic_slice", 0) == 0, (probe, counts)
        assert counts["pallas_call"] >= 1, (probe, counts)


def test_fused_budget_violation_produces_finding():
    cfg = VetConfig()
    cfg.lowering_budgets["pallas_sptc"]["dynamic-slice"] = 0
    # impossible program-count budget: pretend zero fused programs allowed
    cfg.lowering_budgets["pallas_sptc"]["gather"] = -1
    findings, _ = lowering.analyze_pallas_fused(cfg)
    assert any(f.rule == "pallas-fused-gather" for f in findings)


def test_tightened_budget_produces_findings():
    cfg = VetConfig()
    cfg.lowering_backends = ["gemm"]
    cfg.lowering_budgets["gemm"]["gather"] = 0     # forbid the window read
    findings, verdict = lowering.run(cfg)
    assert any(f.rule == "lowering-hot-gather" for f in findings)
    assert not verdict["stencil_gemm"]["certified"]


def test_hot_counts_covers_all_overhead_ops():
    eng_spec = make_stencil("star", 2, 1, seed=7)
    from repro.core.engine import StencilEngine
    report = lowering.lower_engine(StencilEngine(eng_spec, backend="gemm"),
                                   (34, 34))
    counts = lowering.hot_counts(report)
    assert set(counts) == set(lowering.OVERHEAD_OPS) | {"dot"}
    assert counts["dot"] == 2
    assert report.histogram()      # non-empty backward closure


def test_trace_count_is_one_for_fixed_shape():
    from repro.core.engine import StencilEngine
    eng = StencilEngine(make_stencil("star", 2, 1, seed=7), backend="sptc")
    assert lowering.trace_count(eng, (20, 20), calls=3) == 1
