"""FIXTURE (bad): host syncs on the hot path -> code-host-sync."""
import numpy as np


class Driver:
    def submit(self, spec, x):
        depth = np.asarray(x)                # device->host transfer
        return depth

    def _run_batch(self, key, jobs):
        results = [j * 2 for j in jobs]
        results[-1].block_until_ready()      # scheduler thread stalls
        score = float(results[0])            # scalar pull
        return results, score
