"""FIXTURE (bad): jax.jit constructed per request -> code-jit-per-call."""
import jax


class Driver:
    def submit(self, spec, x):
        fn = jax.jit(lambda v: v * 2)        # rebuilt every request
        return fn(x)

    def _run_batch(self, key, jobs):
        out = []
        for j in jobs:
            step = jax.jit(lambda v: v + 1)  # jit inside a loop
            out.append(step(j))
        return out
