"""FIXTURE (bad): mixed locked/unlocked mutation + naked *_locked call."""
import threading


class Scheduler:
    def __init__(self):
        self._lock = threading.Lock()
        self._queue = []
        self.submitted = 0

    def submit(self, job):
        self.submitted += 1                  # unlocked counter bump...
        with self._lock:
            self._queue.append(job)

    def _worker(self):
        with self._lock:
            self.submitted += 1              # ...but locked here: race
            batch = self._pop_ready_locked()
        return batch

    def drain(self):
        return self._pop_ready_locked()      # lock not held!

    def _pop_ready_locked(self):
        with_lock = list(self._queue)
        self._queue.clear()
        return with_lock
