"""FIXTURE (clean twin): device-side ops only on the hot path."""
import jax.numpy as jnp
import numpy as np


class Driver:
    def submit(self, spec, x):
        return jnp.asarray(x)                # device put, not a sync

    def _run_batch(self, key, jobs):
        return [j * 2 for j in jobs]

    def report(self):
        # cold path: syncing here is fine (not a hot-path function)
        return np.asarray(self._last).tolist()
