"""FIXTURE (clean twin): jit built once / memoized -> no findings."""
import jax


class Driver:
    def __init__(self):
        self._fn = jax.jit(lambda v: v * 2)  # constructor: built once
        self._cache = {}

    def submit(self, spec, x):
        return self._fn(x)

    def _run_batch(self, key, jobs):
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(lambda v: v + 1)
            self._cache[key] = fn            # memoized local
        other = self._cache.setdefault(key, None)
        if other is None:
            self._cache[key] = jax.jit(lambda v: v)  # subscript store
        return [fn(j) for j in jobs]
