"""FIXTURE (clean twin): every shared mutation under the lock."""
import threading


class Scheduler:
    def __init__(self):
        self._lock = threading.Lock()
        self._queue = []
        self.submitted = 0

    def submit(self, job):
        with self._lock:
            self.submitted += 1
            self._queue.append(job)

    def _worker(self):
        with self._lock:
            self.submitted += 1
            return self._pop_ready_locked()

    def _drain_locked(self):
        # *_locked caller: lock held by convention, call is fine
        return self._pop_ready_locked()

    def _pop_ready_locked(self):
        batch = list(self._queue)
        self._queue.clear()
        return batch
