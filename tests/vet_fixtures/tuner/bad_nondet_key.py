"""FIXTURE (bad): set iteration order leaks into a cache key."""


def plan_cache_key(spec, backends):
    opts = set(backends)
    return "|".join(opts)                    # order depends on hashing


def spec_fingerprint(spec):
    tags = {spec.shape, str(spec.radius)}
    return str(tags)                         # str() of a set
