"""FIXTURE (clean twin): sets are sorted before entering the key."""


def plan_cache_key(spec, backends):
    opts = set(backends)
    return "|".join(sorted(opts))


def spec_fingerprint(spec):
    tags = {spec.shape, str(spec.radius)}
    return str(sorted(tags))
